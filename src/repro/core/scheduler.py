"""Synergy schedulers: static mapping (SF/SC), work stealing, and the
discrete-event runtime simulator used to reproduce the paper's results
(Fig 9, 11-14, Tables 5/6).

Three scheduling policies from the paper (§3.1.3, §4.3):

  * SF  — static-mapping + fixed-architecture: CONV layers statically
          assigned to the fixed two-cluster config by workload.
  * SC  — static-mapping + custom-architecture: exhaustive search over
          cluster partitions per network (Table 5), still static.
  * WS  — Synergy: same fixed clusters as SF, plus the thief thread
          (manager / idle-book / stealer) moving jobs from busy to idle
          clusters at job granularity.

The simulator is event-driven and models: the two ARM cores as a shared CPU
pool (im2col, pooling, activation, FC, normalization), per-cluster job
queues, per-accelerator service times from the engine cost models in the
``repro.engines`` registry (each ``Accelerator`` is a thin view over its
kind's registered engine), bounded frames-in-flight (the mailbox pipeline
of §3.1), and the stealing protocol.  It is also the planning oracle for
the TPU between-step rebalancer (``lpt_plan`` / ``rebalance``).
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import deque
from typing import Callable, Sequence

from repro.soc.policy import pick_victim, should_steal

from .clusters import (Accelerator, Cluster, arm_cost, cluster_partitions,
                       default_synergy_clusters)
from .job import Job, JobSet

__all__ = [
    "SimLayer", "SimNet", "SimResult", "simulate", "single_thread_latency",
    "sf_layer_map", "search_sc", "lpt_plan", "rebalance",
]


# ---------------------------------------------------------------------------
# Network description for the runtime simulator
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SimLayer:
    """One pipeline stage. ``kind``: 'conv' (accelerated) or 'cpu'."""

    name: str
    kind: str
    jobset: JobSet | None = None   # conv only: per-frame tile jobs
    im2col_bytes: int = 0          # conv only: CPU-side layout transform
    cpu_ops: int = 0               # cpu only: pooling/act/fc op count

    def cpu_time(self) -> float:
        cpu = arm_cost()
        if self.kind == "conv":
            return self.im2col_bytes / cpu.bytes_per_s
        return self.cpu_ops / cpu.ops_per_s


@dataclasses.dataclass(frozen=True)
class SimNet:
    name: str
    layers: tuple[SimLayer, ...]

    @property
    def conv_layers(self) -> list[SimLayer]:
        return [l for l in self.layers if l.kind == "conv"]


@dataclasses.dataclass
class SimResult:
    fps: float
    latency_s: float              # mean steady-state per-frame latency
    utilization: float            # accelerator busy fraction (Table 6 metric)
    per_cluster_busy: dict[str, float]
    per_cluster_runtime: dict[str, float]  # Fig 14 metric: busy s/frame
    makespan_s: float


# ---------------------------------------------------------------------------
# Static layer->cluster mapping (SF) and the SC search
# ---------------------------------------------------------------------------

def sf_layer_map(net: SimNet, clusters: Sequence[Cluster]) -> dict[str, int]:
    """Greedy workload-balanced static map: heavier CONV layers to more
    powerful clusters (§3.1.1 'Mapping of CONV layers and clusters is
    decided by the number of jobs a CONV layer has')."""
    loads = [0.0] * len(clusters)
    mapping: dict[str, int] = {}
    convs = sorted(net.conv_layers, key=lambda l: -l.jobset.total_macs)
    for layer in convs:
        # assign to the cluster minimizing projected finish time
        best = min(range(len(clusters)),
                   key=lambda c: (loads[c] + layer.jobset.total_macs)
                   / max(clusters[c].throughput, 1e-9))
        loads[best] += layer.jobset.total_macs
        mapping[layer.name] = best
    return mapping


def search_sc(net: SimNet, frames: int = 64) -> tuple[list[Cluster], dict[str, int], "SimResult"]:
    """SC: exhaustive cluster-partition search per network (paper Table 5)."""
    best = None
    for clusters in cluster_partitions():
        mapping = sf_layer_map(net, clusters)
        res = simulate(net, clusters, policy="sf", mapping=mapping,
                       frames=frames)
        if best is None or res.fps > best[2].fps:
            best = (clusters, mapping, res)
    return best


# ---------------------------------------------------------------------------
# Event-driven simulator
# ---------------------------------------------------------------------------

_CPU_CORES = 2  # dual-core ARM A9


def simulate(net: SimNet,
             clusters: Sequence[Cluster] | None = None,
             *,
             policy: str = "ws",          # 'ws' | 'sf'
             mapping: dict[str, int] | None = None,
             frames: int = 64,
             inflight: int = 8,
             pipelined: bool = True,
             warmup_frames: int = 8) -> SimResult:
    """Run the Synergy runtime simulator for ``frames`` input frames."""
    clusters = list(clusters) if clusters is not None else default_synergy_clusters()
    if mapping is None:
        mapping = sf_layer_map(net, clusters)

    layers = net.layers
    n_layers = len(layers)
    accs: list[tuple[int, Accelerator]] = []   # (cluster_idx, accelerator)
    for ci, cl in enumerate(clusters):
        for a in cl.accelerators:
            accs.append((ci, a))

    # --- state ------------------------------------------------------------
    queues: list[deque] = [deque() for _ in clusters]   # per-cluster job queues
    acc_free = [True] * len(accs)
    acc_busy_time = [0.0] * len(accs)
    cpu_free = _CPU_CORES
    cpu_queue: deque = deque()           # (duration, callback)
    remaining: dict[tuple[int, int], int] = {}   # (layer, frame) -> jobs left
    frame_admit_t: dict[int, float] = {}
    frame_done_t: dict[int, float] = {}
    events: list = []                    # (time, seq, fn)
    seq = itertools.count()
    now = 0.0

    def push(t: float, fn: Callable[[], None]) -> None:
        heapq.heappush(events, (t, next(seq), fn))

    # --- CPU pool -----------------------------------------------------------
    def cpu_submit(duration: float, done_cb: Callable[[], None]) -> None:
        nonlocal cpu_free
        if cpu_free > 0:
            cpu_free -= 1
            push(now + duration, lambda: _cpu_done(done_cb))
        else:
            cpu_queue.append((duration, done_cb))

    def _cpu_done(done_cb: Callable[[], None]) -> None:
        nonlocal cpu_free
        if cpu_queue:
            duration, cb = cpu_queue.popleft()
            push(now + duration, lambda: _cpu_done(cb))
        else:
            cpu_free += 1
        done_cb()

    # --- accelerators + work stealing --------------------------------------
    def try_dispatch(acc_idx: int) -> None:
        ci, acc = accs[acc_idx]
        if not acc_free[acc_idx]:
            return
        job = None
        if queues[ci]:
            job = queues[ci].popleft()
        elif policy == "ws":
            # thief thread: manager sees this cluster idle; stealer takes a
            # job from the busiest victim queue (job-level granularity —
            # §4.3 "work-stealing ... at the granularity of job-level").
            # The decision is the SHARED policy in repro.soc.policy — the
            # live SynergyRuntime and SimRuntime apply the same tail guard.
            victim = pick_victim([len(q) for q in queues])
            if should_steal(acc.rel_rate, len(queues[victim])):
                job = queues[victim].popleft()
        if job is None:
            return
        layer_idx, frame, macs = job
        dt = acc.job_time(macs)
        acc_free[acc_idx] = False
        acc_busy_time[acc_idx] += dt
        push(now + dt, lambda: _acc_done(acc_idx, layer_idx, frame))

    def _acc_done(acc_idx: int, layer_idx: int, frame: int) -> None:
        acc_free[acc_idx] = True
        remaining[(layer_idx, frame)] -= 1
        if remaining[(layer_idx, frame)] == 0:
            frame_at(layer_idx + 1, frame)
        try_dispatch(acc_idx)

    def kick_cluster(ci: int) -> None:
        for ai, (c, _) in enumerate(accs):
            if c == ci and acc_free[ai]:
                try_dispatch(ai)
        if policy == "ws":
            for ai in range(len(accs)):
                if acc_free[ai]:
                    try_dispatch(ai)

    # --- pipeline flow -------------------------------------------------------
    def frame_at(layer_idx: int, frame: int) -> None:
        if layer_idx == n_layers:
            frame_done_t[frame] = now
            nxt = max(frame_admit_t) + 1 if frame_admit_t else 0
            if nxt < frames and len(frame_admit_t) - len(frame_done_t) < inflight:
                admit(nxt)
            return
        layer = layers[layer_idx]
        if layer.kind == "conv":
            def after_im2col(li=layer_idx, f=frame, lay=layer):
                js = lay.jobset
                n_jobs = js.num_jobs
                remaining[(li, f)] = n_jobs
                ci = mapping[lay.name]
                per_job_macs = js.total_macs // n_jobs
                for _ in range(n_jobs):
                    queues[ci].append((li, f, per_job_macs))
                kick_cluster(ci)
            cpu_submit(layer.cpu_time(), after_im2col)
        else:
            cpu_submit(layer.cpu_time(), lambda li=layer_idx, f=frame: frame_at(li + 1, f))

    def admit(frame: int) -> None:
        frame_admit_t[frame] = now
        frame_at(0, frame)

    # --- run -----------------------------------------------------------------
    init = inflight if pipelined else 1
    for f in range(min(init, frames)):
        admit(f)
    # sequential (non-pipelined) mode admits the next frame on completion,
    # which frame_at() already does; with inflight=1 that's sequential.
    if not pipelined:
        inflight = 1

    while events and len(frame_done_t) < frames:
        now, _, fn = heapq.heappop(events)
        fn()

    makespan = now
    done = sorted(frame_done_t)
    # steady-state window: skip at least the initial admission burst
    # (`inflight` frames complete in a bunch) plus the warmup allowance —
    # otherwise short runs overestimate fps beyond the physical pool rate.
    w = min(max(warmup_frames, inflight), max(0, len(done) - 2))
    t0 = frame_done_t[done[w]] if len(done) > w else 0.0
    steady = len(done) - 1 - w
    fps = steady / (makespan - t0) if steady > 0 and makespan > t0 else (
        len(done) / makespan if makespan > 0 else 0.0)
    lat = sum(frame_done_t[f] - frame_admit_t[f] for f in done[w:]) / max(1, len(done) - w)

    per_cluster_busy: dict[str, float] = {}
    per_cluster_runtime: dict[str, float] = {}
    util_num = util_den = 0.0
    i = 0
    for ci, cl in enumerate(clusters):
        busy = sum(acc_busy_time[i + j] for j in range(len(cl)))
        per_cluster_busy[cl.name] = busy / (len(cl) * makespan) if makespan else 0.0
        per_cluster_runtime[cl.name] = busy / max(1, len(done))
        util_num += busy
        util_den += len(cl) * makespan
        i += len(cl)
    return SimResult(fps=fps, latency_s=lat,
                     utilization=util_num / util_den if util_den else 0.0,
                     per_cluster_busy=per_cluster_busy,
                     per_cluster_runtime=per_cluster_runtime,
                     makespan_s=makespan)


# ---------------------------------------------------------------------------
# Software-only baselines
# ---------------------------------------------------------------------------

def single_thread_latency(net: SimNet) -> float:
    """Original Darknet: one ARM core does everything (paper's baseline)."""
    t = 0.0
    cpu = arm_cost()
    for layer in net.layers:
        t += layer.cpu_time()
        if layer.kind == "conv":
            t += layer.jobset.useful_macs / cpu.macs_per_s
    return t


# ---------------------------------------------------------------------------
# Production planner: the work-stealing insight as a between-step rebalancer
# ---------------------------------------------------------------------------

def lpt_plan(jobsets: Sequence[JobSet], clusters: Sequence[Cluster]) -> list[list[int]]:
    """Longest-processing-time assignment of job *sets* to clusters,
    proportional to cluster throughput — the static seed plan (SF analog).
    Returns, per cluster, the list of jobset indices."""
    order = sorted(range(len(jobsets)), key=lambda i: -jobsets[i].total_macs)
    loads = [0.0] * len(clusters)
    plan: list[list[int]] = [[] for _ in clusters]
    for i in order:
        c = min(range(len(clusters)),
                key=lambda ci: (loads[ci] + jobsets[i].total_macs)
                / max(clusters[ci].throughput, 1e-9))
        loads[c] += jobsets[i].total_macs
        plan[c].append(i)
    return plan


def rebalance(shares: Sequence[float], measured_s: Sequence[float],
              ema: float = 0.5) -> list[float]:
    """Between-step work stealing for SPMD: given the current work shares and
    the measured per-cluster step times, shift share from slow to fast
    clusters so projected times equalize.  EMA damps oscillation.

    shares sum to 1; measured_s are wall times of the last step."""
    rates = [s / t if t > 0 else 0.0 for s, t in zip(shares, measured_s)]
    total_rate = sum(rates)
    if total_rate <= 0:
        return list(shares)
    target = [r / total_rate for r in rates]
    out = [(1 - ema) * s + ema * t for s, t in zip(shares, target)]
    norm = sum(out)
    return [s / norm for s in out]
