"""Heterogeneous accelerator clusters (paper §3.1.1 "Accelerator Clusters").

The paper's prototype uses a fixed, network-agnostic accelerator set on the
Zynq XC7Z020: 6 fast FPGA PEs (F-PE), 2 slow PEs (S-PE) and 2 NEON cores,
grouped into clusters with private job queues.  We model each accelerator by
a calibrated *rate* (sustained MAC/s on 32x32xk tile jobs) plus a per-job
dispatch overhead (the ReconOS delegate-thread round trip).

Calibration (documented, used by the discrete-event simulator that reproduces
the paper's Figures 9/13/14 and Table 6):

  * F-PE: HLS loop pipelining at loop2, II limited by BRAM ports to TS/2=16
    cycles per merged iteration -> ~2 MAC/cycle @ 100 MHz = 0.2 GMAC/s.
  * S-PE: unroll(2) + pipelining at loop3 -> ~1 MAC/cycle = 0.1 GMAC/s (0.5x).
  * NEON: calibrated from the paper's measurement that adding 2 NEONs to the
    6F+2S FPGA config improves latency by ~12% (Fig 11): 2*x = 0.12*7.0
    F-PE-units -> x = 0.42 F-PE-units = 0.084 GMAC/s.
  * ARM A9 scalar (Darknet -O3): from Table 3, original single-thread design
    sustains ~0.21 GOPS => ~0.105 GMAC/s on conv; other layers modeled at
    0.5 Gop/s; im2col at 0.8 GB/s effective copy bandwidth.

At pod scale the same abstraction describes *device groups* of a TPU mesh
(possibly heterogeneous across generations or degraded/straggler nodes); the
between-step rebalancer in ``repro.runtime.straggler`` consumes the same
``Cluster`` objects.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

__all__ = [
    "Accelerator", "Cluster", "F_PE", "S_PE", "NEON",
    "default_synergy_clusters", "make_accelerators", "CPU_CONV_MACS_PER_S",
    "CPU_OTHER_OPS_PER_S", "CPU_COPY_BYTES_PER_S", "JOB_DISPATCH_S",
]

# --- calibrated constants (see module docstring) ---------------------------
# F-PE sustained rate: ~2 MAC/cycle pipelined minus BRAM-port stalls and
# job-fetch gaps -> 0.125 GMAC/s.  Together with the ARM rate below this
# centers the simulator on the paper's absolutes: ~7.3x mean speedup (Fig 9),
# 39.5-136.4 fps band (Table 4), SF util ~92.5% (Table 6).
F_PE_MACS_PER_S = 0.125e9
JOB_DISPATCH_S = 30e-6          # delegate-thread round trip per job
CPU_CONV_MACS_PER_S = 0.14e9    # ARM A9, Darknet gemm -O3, single thread
CPU_OTHER_OPS_PER_S = 0.5e9     # pool/act/fc elementwise+gemv rate
CPU_COPY_BYTES_PER_S = 0.8e9    # im2col / layout transforms


@dataclasses.dataclass(frozen=True)
class Accelerator:
    """One PE/NEON: ``rate`` in F-PE units (F-PE == 1.0)."""

    name: str
    kind: str          # 'F-PE' | 'S-PE' | 'NEON' | 'TPU-slice'
    rate: float        # relative to F-PE
    dispatch_s: float = JOB_DISPATCH_S

    @property
    def macs_per_s(self) -> float:
        return self.rate * F_PE_MACS_PER_S

    def job_time(self, job_macs: int) -> float:
        return job_macs / self.macs_per_s + self.dispatch_s


def F_PE(i: int) -> Accelerator:
    return Accelerator(f"F-PE{i}", "F-PE", 1.0)


def S_PE(i: int) -> Accelerator:
    return Accelerator(f"S-PE{i}", "S-PE", 0.5)


def NEON(i: int) -> Accelerator:
    return Accelerator(f"NEON{i}", "NEON", 0.42)


@dataclasses.dataclass(frozen=True)
class Cluster:
    """A named group of accelerators with a private job queue (§3.1.1)."""

    name: str
    accelerators: tuple[Accelerator, ...]

    @property
    def throughput(self) -> float:
        """Aggregate rate in F-PE units (used by the LPT planner)."""
        return sum(a.rate for a in self.accelerators)

    def __len__(self) -> int:
        return len(self.accelerators)


def make_accelerators(n_fpe: int, n_spe: int, n_neon: int) -> list[Accelerator]:
    return ([F_PE(i) for i in range(n_fpe)]
            + [S_PE(i) for i in range(n_spe)]
            + [NEON(i) for i in range(n_neon)])


def default_synergy_clusters() -> list[Cluster]:
    """The paper's fixed two-cluster config used across ALL benchmarks:
    Cluster-0: 2 NEONs + 2 S-PE;  Cluster-1: 6 F-PE  (§4, 'Synergy uses two
    clusters ... across all benchmarks')."""
    c0 = Cluster("Cluster-0", tuple([NEON(0), NEON(1), S_PE(0), S_PE(1)]))
    c1 = Cluster("Cluster-1", tuple(F_PE(i) for i in range(6)))
    return [c0, c1]


def cluster_partitions(n_fpe: int = 6, n_spe: int = 2, n_neon: int = 2):
    """Enumerate all two-cluster splits of the accelerator pool — the SC
    (static-custom) design space the paper searches (Table 5 footnote: any
    number of clusters; two suffices for these nets)."""
    for f0 in range(n_fpe + 1):
        for s0 in range(n_spe + 1):
            for n0 in range(n_neon + 1):
                a0 = make_accelerators(f0, s0, n0)
                a1 = ([F_PE(i + f0) for i in range(n_fpe - f0)]
                      + [S_PE(i + s0) for i in range(n_spe - s0)]
                      + [NEON(i + n0) for i in range(n_neon - n0)])
                if not a0 or not a1:
                    continue
                yield [Cluster("Cluster-0", tuple(a0)),
                       Cluster("Cluster-1", tuple(a1))]
