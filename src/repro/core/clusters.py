"""Heterogeneous accelerator clusters (paper §3.1.1 "Accelerator Clusters").

The paper's prototype uses a fixed, network-agnostic accelerator set on the
Zynq XC7Z020: 6 fast FPGA PEs (F-PE), 2 slow PEs (S-PE) and 2 NEON cores,
grouped into clusters with private job queues.

Each :class:`Accelerator` is a THIN VIEW over the engine registry
(:mod:`repro.engines`): its kind names a registered simulated engine
(``F-PE`` / ``S-PE`` / ``NEON`` / ``ARM``) whose :class:`CostModel` carries
the calibrated rates — see ``repro.engines.sim`` for the calibration notes.
Accelerator views read the registry LIVE — re-registering a kind's engine
re-rates every accelerator, cluster, simulator run, and planner at once.
The module-level rate constants are import-time snapshots kept only for
backward compatibility; new code should go through ``Accelerator.cost`` /
``arm_cost()``.

At pod scale the same abstraction describes *device groups* of a TPU mesh
(possibly heterogeneous across generations or degraded/straggler nodes); the
between-step rebalancer in ``repro.runtime.straggler`` consumes the same
``Cluster`` objects.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.engines import CostModel, find_engine, get_engine

__all__ = [
    "Accelerator", "Cluster", "F_PE", "S_PE", "NEON",
    "default_synergy_clusters", "make_accelerators", "arm_cost",
    "CPU_CONV_MACS_PER_S", "CPU_OTHER_OPS_PER_S", "CPU_COPY_BYTES_PER_S",
    "JOB_DISPATCH_S", "F_PE_MACS_PER_S",
]


def arm_cost() -> CostModel:
    """The host-CPU cost model (im2col / pooling / act / fc stages)."""
    return get_engine("ARM").cost


def _kind_cost(kind: str) -> CostModel:
    return get_engine(kind).cost


# --- registry-derived aliases (the single source is repro.engines.sim) -----
F_PE_MACS_PER_S = _kind_cost("F-PE").macs_per_s
JOB_DISPATCH_S = _kind_cost("F-PE").dispatch_s
CPU_CONV_MACS_PER_S = arm_cost().macs_per_s
CPU_OTHER_OPS_PER_S = arm_cost().ops_per_s
CPU_COPY_BYTES_PER_S = arm_cost().bytes_per_s


def _rel_rate(kind: str) -> float:
    """Registered kind rate expressed in F-PE units (live registry read)."""
    eng, base = find_engine(kind), find_engine("F-PE")
    if eng is None or base is None:
        return 1.0
    return eng.cost.macs_per_s / base.cost.macs_per_s


@dataclasses.dataclass(frozen=True)
class Accelerator:
    """One PE/NEON — a THIN VIEW over the engine registry.

    ``rate`` (F-PE units; F-PE == 1.0) and ``dispatch_s`` default to None,
    meaning "track the registered engine of my ``kind`` live" — so
    re-registering a kind's engine re-rates every existing Accelerator,
    cluster, and planner at once.  Explicit values pin a custom rate
    (degraded nodes, hypothetical hardware)."""

    name: str
    kind: str          # 'F-PE' | 'S-PE' | 'NEON' | 'TPU-slice' | engine name
    rate: float | None = None        # relative to F-PE; None = registry
    dispatch_s: float | None = None  # None = kind engine's dispatch

    @property
    def rel_rate(self) -> float:
        """Throughput in F-PE units (LPT planner / steal-guard metric)."""
        return self.rate if self.rate is not None else _rel_rate(self.kind)

    @property
    def cost(self) -> CostModel:
        """This accelerator's cost model view over the registry."""
        eng = find_engine(self.kind)
        if self.rate is None and eng is not None:
            base = eng.cost
        else:
            fpe = find_engine("F-PE")
            per_fpe = fpe.cost.macs_per_s if fpe is not None else F_PE_MACS_PER_S
            base = CostModel(macs_per_s=self.rel_rate * per_fpe,
                             dispatch_s=(eng.cost.dispatch_s if eng is not None
                                         else JOB_DISPATCH_S))
        if self.dispatch_s is not None:
            base = dataclasses.replace(base, dispatch_s=self.dispatch_s)
        return base

    @property
    def macs_per_s(self) -> float:
        return self.cost.macs_per_s

    def job_time(self, job_macs: int) -> float:
        return self.cost.job_time(job_macs)


def F_PE(i: int) -> Accelerator:
    return Accelerator(f"F-PE{i}", "F-PE")


def S_PE(i: int) -> Accelerator:
    return Accelerator(f"S-PE{i}", "S-PE")


def NEON(i: int) -> Accelerator:
    return Accelerator(f"NEON{i}", "NEON")


@dataclasses.dataclass(frozen=True)
class Cluster:
    """A named group of accelerators with a private job queue (§3.1.1)."""

    name: str
    accelerators: tuple[Accelerator, ...]

    @property
    def throughput(self) -> float:
        """Aggregate rate in F-PE units (used by the LPT planner)."""
        return sum(a.rel_rate for a in self.accelerators)

    def __len__(self) -> int:
        return len(self.accelerators)


def make_accelerators(n_fpe: int, n_spe: int, n_neon: int) -> list[Accelerator]:
    return ([F_PE(i) for i in range(n_fpe)]
            + [S_PE(i) for i in range(n_spe)]
            + [NEON(i) for i in range(n_neon)])


def default_synergy_clusters() -> list[Cluster]:
    """The paper's fixed two-cluster config used across ALL benchmarks:
    Cluster-0: 2 NEONs + 2 S-PE;  Cluster-1: 6 F-PE  (§4, 'Synergy uses two
    clusters ... across all benchmarks')."""
    c0 = Cluster("Cluster-0", tuple([NEON(0), NEON(1), S_PE(0), S_PE(1)]))
    c1 = Cluster("Cluster-1", tuple(F_PE(i) for i in range(6)))
    return [c0, c1]


def cluster_partitions(n_fpe: int = 6, n_spe: int = 2, n_neon: int = 2):
    """Enumerate all two-cluster splits of the accelerator pool — the SC
    (static-custom) design space the paper searches (Table 5 footnote: any
    number of clusters; two suffices for these nets)."""
    for f0 in range(n_fpe + 1):
        for s0 in range(n_spe + 1):
            for n0 in range(n_neon + 1):
                a0 = make_accelerators(f0, s0, n0)
                a1 = ([F_PE(i + f0) for i in range(n_fpe - f0)]
                      + [S_PE(i + s0) for i in range(n_spe - s0)]
                      + [NEON(i + n0) for i in range(n_neon - n0)])
                if not a0 or not a1:
                    continue
                yield [Cluster("Cluster-0", tuple(a0)),
                       Cluster("Cluster-1", tuple(a1))]
