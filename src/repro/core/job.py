"""Job: the workload granularity of Synergy (paper Listing 2 / Fig 3).

A *job* is the computation that produces one output tile ``C(t1, t2)`` of a
tiled matrix multiplication ``C[m, n] = A[m, k] @ B[k, n]``.  The paper's job
structure carries base addresses, GEMM dims, tile indices and the owning
layer id; addresses are meaningless in JAX, so the job here is pure metadata
used by the schedulers, cost models, and the roofline analysis.  The actual
tile compute is executed by the Pallas ``tiled_mm`` kernel whose grid *is*
the job space.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterator, Optional, Sequence

__all__ = ["Job", "JobSet", "ceil_div", "chunk_by_macs"]


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class Job:
    """One tile job (paper Listing 2, minus raw pointers)."""

    layer_id: int
    t1: int  # output tile row index
    t2: int  # output tile col index
    m: int   # full GEMM rows
    n: int   # full GEMM cols
    k: int   # full GEMM contraction
    ts_m: int
    ts_n: int
    ts_k: int

    # ---- geometry -------------------------------------------------------
    @property
    def rows(self) -> int:
        """Valid rows in this tile (border tiles are zero-padded, §3.2.1)."""
        return min(self.ts_m, self.m - self.t1 * self.ts_m)

    @property
    def cols(self) -> int:
        return min(self.ts_n, self.n - self.t2 * self.ts_n)

    @property
    def is_border(self) -> bool:
        return self.rows < self.ts_m or self.cols < self.ts_n

    # ---- cost model inputs ----------------------------------------------
    @property
    def macs(self) -> int:
        """MACs actually executed: the fixed-size PE always computes the
        full padded tile (the paper's PEs do too — zero padding, not
        shortened loops)."""
        return self.ts_m * self.ts_n * self.k

    @property
    def flops(self) -> int:
        return 2 * self.macs

    @property
    def bytes_moved(self) -> int:
        """HBM traffic for the job: stream a row-panel of A and a col-panel
        of B, write one C tile (fp32 = 4B; the paper uses fp32 throughout)."""
        return 4 * (self.ts_m * self.k + self.k * self.ts_n + self.ts_m * self.ts_n)


@dataclasses.dataclass(frozen=True)
class JobSet:
    """All jobs of one GEMM (one CONV layer after im2col, or one LM matmul)."""

    layer_id: int
    m: int
    n: int
    k: int
    ts_m: int
    ts_n: int
    ts_k: int
    name: str = ""

    @classmethod
    def for_gemm(cls, layer_id: int, m: int, n: int, k: int,
                 tile: int | tuple[int, int, int] = 32, name: str = "") -> "JobSet":
        if isinstance(tile, int):
            tile = (tile, tile, tile)
        ts_m, ts_n, ts_k = tile
        return cls(layer_id=layer_id, m=m, n=n, k=k,
                   ts_m=ts_m, ts_n=ts_n, ts_k=ts_k, name=name)

    @classmethod
    def for_conv(cls, layer_id: int, n_frames: int, h: int, w: int,
                 cin: int, cout: int, kernel: int, stride: int = 1,
                 padding: int = 0, tile: int | tuple[int, int, int] = 32,
                 name: str = "") -> "JobSet":
        """The im2col GEMM of one CONV layer over an ``n_frames`` image
        batch (§3.1.1): ``m = n_frames * oh * ow``, ``k = kernel² * cin``,
        ``n = cout`` — the REAL conv-as-GEMM shape the serving prefill
        path and the DES both account (one source of truth, so server
        busy-seconds and simulator busy-seconds agree by construction)."""
        oh = (h + 2 * padding - kernel) // stride + 1
        ow = (w + 2 * padding - kernel) // stride + 1
        return cls.for_gemm(layer_id, n_frames * oh * ow, cout,
                            kernel * kernel * cin, tile, name=name)

    @property
    def grid(self) -> tuple[int, int]:
        return (ceil_div(self.m, self.ts_m), ceil_div(self.n, self.ts_n))

    @property
    def num_jobs(self) -> int:
        g = self.grid
        return g[0] * g[1]

    @property
    def k_steps(self) -> int:
        return ceil_div(self.k, self.ts_k)

    def jobs(self) -> Iterator[Job]:
        gm, gn = self.grid
        for t1 in range(gm):
            for t2 in range(gn):
                yield Job(self.layer_id, t1, t2, self.m, self.n, self.k,
                          self.ts_m, self.ts_n, self.ts_k)

    # aggregate costs -------------------------------------------------------
    @property
    def total_macs(self) -> int:
        return self.num_jobs * self.ts_m * self.ts_n * self.k

    @property
    def useful_macs(self) -> int:
        return self.m * self.n * self.k

    @property
    def padding_waste(self) -> float:
        """Fraction of MACs spent on zero-padded borders (fixed-size PE tax)."""
        return 1.0 - self.useful_macs / max(1, self.total_macs)

    @property
    def total_flops(self) -> int:
        return 2 * self.total_macs


def total_jobs(jobsets: Sequence[JobSet]) -> int:
    return sum(js.num_jobs for js in jobsets)


def chunk_by_macs(jobsets: Sequence[JobSet],
                  budget_macs: Optional[int]) -> list[list[int]]:
    """Group consecutive jobsets into bounded-cost chunks: each chunk's
    summed ``total_macs`` stays under ``budget_macs`` where possible (a
    single jobset over budget still gets its own chunk — order is never
    broken, so layer dependencies survive the split).  ``None`` or a
    non-positive budget means ONE chunk.  Returns index groups, the unit
    of chunked prefill: the serving engine submits one group per step so
    a large admission wave cannot flood the queues ahead of decode."""
    n = len(jobsets)
    if not n:
        return []
    if not budget_macs or budget_macs <= 0:
        return [list(range(n))]
    chunks: list[list[int]] = []
    cur: list[int] = []
    cur_macs = 0
    for i, js in enumerate(jobsets):
        if cur and cur_macs + js.total_macs > budget_macs:
            chunks.append(cur)
            cur, cur_macs = [], 0
        cur.append(i)
        cur_macs += js.total_macs
    chunks.append(cur)
    return chunks


def arithmetic_intensity(js: JobSet) -> float:
    """FLOPs per HBM byte for one job — drives tile-size selection (§Perf)."""
    j = next(js.jobs())
    return j.flops / j.bytes_moved
