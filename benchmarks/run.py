"""Benchmark harness: one function per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV per the harness contract, and dumps
full rows to results/benchmarks.json.
"""

from __future__ import annotations

import json
import os
import sys
import time

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)   # make `benchmarks.*` importable as a script


def main() -> None:
    from benchmarks.paper_figs import ALL

    os.makedirs("results", exist_ok=True)
    full = {}
    print("name,us_per_call,derived")
    for name, fn in ALL.items():
        t0 = time.perf_counter()
        rows, derived = fn()
        dt_us = (time.perf_counter() - t0) * 1e6
        full[name] = {"rows": rows, "derived": derived,
                      "us_per_call": dt_us}
        key = next(iter(derived))
        val = derived[key]
        if isinstance(val, dict):
            val = json.dumps(val).replace(",", ";")
        print(f"{name},{dt_us:.0f},{key}={val}")

    # roofline summary (reads results/dryrun if present)
    try:
        from benchmarks.roofline import build_table
        rows = build_table(mesh="16x16")
        cells = [r for r in rows if "skipped" not in r]
        if cells:
            mean_frac = sum(r["roofline_fraction"] for r in cells) / len(cells)
            full["roofline"] = {"rows": rows}
            print(f"roofline_16x16,0,mean_fraction={mean_frac:.3f} "
                  f"over {len(cells)} cells")
    except Exception as e:  # dry-run not yet executed
        print(f"roofline_16x16,0,unavailable({type(e).__name__})")

    # per-engine telemetry accumulated by the unified dispatch surface
    from repro.engines import list_engines
    engines = {}
    for eng in list_engines():
        t = eng.telemetry
        if t.gemms:
            engines[eng.name] = {"gemms": t.gemms, "jobs": t.jobs,
                                 "busy_s_est": t.busy_s,
                                 "bytes_moved": t.bytes_moved}
            print(f"engine_{eng.name},0,jobs={t.jobs}")
    full["engine_telemetry"] = engines

    with open("results/benchmarks.json", "w") as f:
        json.dump(full, f, indent=1, default=str)


if __name__ == "__main__":
    main()
