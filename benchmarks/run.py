"""Benchmark harness: one function per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV per the harness contract, and dumps
full rows to a timestamped ``results/benchmarks-<UTC stamp>.json`` (plus a
``results/latest.json`` pointer) so successive runs never clobber each
other.

``--filter SUBSTR[,SUBSTR...]`` runs only benchmarks whose name contains
any listed substring; ``--smoke`` shrinks the simulated frame counts for
CI smoke jobs (``--filter quant,qmm --smoke`` is the CI benchmark-smoke
invocation, gated afterwards by ``check_regression.py`` against the
committed ``results/latest.json`` baseline).
"""

from __future__ import annotations

import argparse
import datetime
import json
import os
import sys
import time

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)   # make `benchmarks.*` importable as a script

#: frame count substituted for paper_figs.FRAMES under --smoke
_SMOKE_FRAMES = 8


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--filter", default="", metavar="SUBSTR[,SUBSTR...]",
                        help="run only benchmarks whose name contains any "
                             "of these comma-separated substrings")
    parser.add_argument("--smoke", action="store_true",
                        help="shrink frame counts (CI smoke mode)")
    parser.add_argument("--trace", default="", metavar="OUT.json",
                        help="record every live-runtime benchmark on one "
                             "tracer and write a Chrome trace_event JSON "
                             "(open in chrome://tracing or ui.perfetto.dev)")
    args = parser.parse_args(argv)

    # SIGTERM mid-benchmark (CI timeout, operator ctrl) gracefully drains
    # any durable server a benchmark has live — clean final snapshot and
    # a closed journal instead of a dead pool and a torn tail
    from repro.soc import install_sigterm_handler
    install_sigterm_handler()

    tracer = None
    if args.trace:
        # process-default tracer: benchmarks construct their runtimes
        # internally, so installing the default is how --trace sees them
        from repro.obs.trace import Tracer, set_default_tracer
        tracer = Tracer(capacity=1_000_000)
        set_default_tracer(tracer)

    from benchmarks import paper_figs
    if args.smoke:
        paper_figs.FRAMES = _SMOKE_FRAMES
    # drop empty segments: a trailing comma must not silently select ALL
    # benchmarks (the smoke gate would then compare FRAMES=8 DES fps
    # against the full-frame baseline and fail spuriously)
    tokens = [t for t in args.filter.split(",") if t] or [""]
    selected = {name: fn for name, fn in paper_figs.ALL.items()
                if any(tok in name for tok in tokens)}
    if not selected:
        parser.error(f"--filter {args.filter!r} matches no benchmark "
                     f"(known: {sorted(paper_figs.ALL)})")

    os.makedirs("results", exist_ok=True)
    full = {}
    print("name,us_per_call,derived")
    for name, fn in selected.items():
        t0 = time.perf_counter()
        rows, derived = fn()
        dt_us = (time.perf_counter() - t0) * 1e6
        full[name] = {"rows": rows, "derived": derived,
                      "us_per_call": dt_us}
        key = next(iter(derived))
        val = derived[key]
        if isinstance(val, dict):
            val = json.dumps(val).replace(",", ";")
        print(f"{name},{dt_us:.0f},{key}={val}")

    # roofline summary (reads results/dryrun if present; a name filter
    # means a targeted run — skip the cross-cutting summary)
    if not args.filter:
        try:
            from benchmarks.roofline import build_table
            rows = build_table(mesh="16x16")
            cells = [r for r in rows if "skipped" not in r]
            if cells:
                mean_frac = (sum(r["roofline_fraction"] for r in cells)
                             / len(cells))
                full["roofline"] = {"rows": rows}
                print(f"roofline_16x16,0,mean_fraction={mean_frac:.3f} "
                      f"over {len(cells)} cells")
        except Exception as e:  # dry-run not yet executed
            print(f"roofline_16x16,0,unavailable({type(e).__name__})")

    # per-engine telemetry accumulated by the unified dispatch surface AND
    # the work-stealing runtime (same counters the Table-6 metric reads)
    from repro.engines import list_engines
    engines = {}
    for eng in list_engines():
        t = eng.telemetry
        if t.gemms or t.jobs:
            engines[eng.name] = {"gemms": t.gemms, "jobs": t.jobs,
                                 "busy_s_est": t.busy_s,
                                 "bytes_moved": t.bytes_moved,
                                 "steals": t.steals,
                                 "wall_busy_s": t.wall_busy_s,
                                 "idle_s": t.idle_s,
                                 "busy_fraction": t.busy_fraction}
            print(f"engine_{eng.name},0,jobs={t.jobs};steals={t.steals}")
    full["engine_telemetry"] = engines

    if tracer is not None:
        n_ev = tracer.export_chrome_trace(args.trace)
        counts = ";".join(f"{k}={v}" for k, v in
                          sorted(tracer.counts().items()))
        full["trace"] = {"path": args.trace, "trace_events": n_ev,
                         "dropped": tracer.dropped,
                         "counts": tracer.counts()}
        print(f"trace,0,path={args.trace};events={n_ev};{counts}")

    stamp = datetime.datetime.now(datetime.timezone.utc).strftime(
        "%Y%m%dT%H%M%SZ")
    out_path = os.path.join("results", f"benchmarks-{stamp}.json")
    with open(out_path, "w") as f:
        json.dump(full, f, indent=1, default=str)
    # stable pointer for tooling that wants "the most recent run"
    with open(os.path.join("results", "latest.json"), "w") as f:
        json.dump({"path": out_path, "stamp": stamp}, f, indent=1)
    print(f"results_path,0,{out_path}")


if __name__ == "__main__":
    main()
