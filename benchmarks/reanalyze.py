"""Re-run the HLO analyzer over archived .hlo.zst artifacts and patch the
dry-run JSON records in place — lets analyzer iterations (and §Perf
accounting fixes) be re-measured without recompiling 80 cells.

    PYTHONPATH=src python -m benchmarks.reanalyze [results/dryrun]
"""

from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import zstandard  # noqa: E402

from repro.launch.hlo_analysis import analyze_hlo  # noqa: E402


def main():
    out = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    dctx = zstandard.ZstdDecompressor()
    n = 0
    for jf in sorted(glob.glob(os.path.join(out, "*.json"))):
        rec = json.load(open(jf))
        if rec.get("status") != "ok":
            continue
        tag = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}"
        hf = os.path.join(out, "hlo", tag + ".hlo.zst")
        if not os.path.exists(hf):
            continue
        text = dctx.decompress(open(hf, "rb").read()).decode()
        rec["hlo_accounting"] = analyze_hlo(text).to_dict()
        rec["analyzer_version"] = 5
        json.dump(rec, open(jf, "w"), indent=1)
        n += 1
    print(f"re-analyzed {n} records")


if __name__ == "__main__":
    main()
