"""Benchmark regression gate: compare a fresh run against a baseline.

    python benchmarks/check_regression.py --baseline /tmp/baseline.json \
        --new results/latest.json [--max-drop 0.20]

Either argument may be a ``results/latest.json`` POINTER ({"path": ...})
or a full benchmark dump.  The comparison extracts every numeric
``fps``-like field (``fps``, ``weighted_fps``, per-mode/pool/net rows)
from benchmarks present in BOTH runs and fails (exit 1) when any
simulated-fps value drops more than ``--max-drop`` relative to the
baseline.  New benchmarks (present only in the new run) and wall-clock
fields are ignored — the gate protects the DES/virtual-time throughput
claims, which are deterministic up to cost-model edits, not host timing.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

#: row fields that identify a row within a benchmark's table
_ROW_KEYS = ("net", "pool", "mode", "design", "leg", "shape")

#: numeric fields treated as simulated-fps claims.  ``tokens_per_s_rel``
#: is the serving-throughput gate (ISSUE 5): each serve mode's tokens/s
#: RELATIVE to the per-request baseline measured in the same run — a
#: machine-stable ratio (both legs share the host), unlike the raw
#: ``tokens_per_s_wall`` fields, which stay ungated wall-clock telemetry.
_FPS_FIELDS = ("fps", "weighted_fps", "sf_fps", "sc_fps", "ws_fps",
               "fpga_fps", "het_fps", "tokens_per_s_rel",
               "prefill_overlap_rel", "decode_p99_rel",
               "slo_attainment_rel", "recovery_fps_rel",
               "trace_overhead_rel", "fault_recovery_rel",
               "restart_recovery_rel")

#: ABSOLUTE floors, checked on the NEW run alone (no baseline needed):
#: a ratio below its floor fails even if the baseline was also below it.
#: ``trace_overhead_rel`` is the ISSUE 8 observability gate — the span
#: tracer may cost at most 5% fps on the paced pool when enabled.
#: ``fault_recovery_rel`` is the ISSUE 9 fault-recovery gate — a pool
#: that loses an engine mid-run must keep >= 0.8x clean throughput once
#: the orphaned panels re-seed onto the survivors.
#: ``restart_recovery_rel`` is the ISSUE 10 durable-serving gate — a
#: server restored from a crash (snapshot + journal replay) must keep
#: >= 0.8x a clean durable server's steady-state tokens/s.
_FLOOR_FIELDS = {"trace_overhead_rel": 0.95, "fault_recovery_rel": 0.8,
                 "restart_recovery_rel": 0.8}


def load_run(path: str) -> dict:
    """Load a benchmark dump, following a latest.json pointer if given."""
    with open(path) as f:
        data = json.load(f)
    if "path" in data and set(data) <= {"path", "stamp"}:   # pointer file
        target = data["path"]
        if not os.path.isabs(target):
            # the pointer records a repo-root-relative path; a snapshot
            # copied elsewhere still points back into the repo, so try
            # the cwd first, then next to the pointer itself
            candidates = (
                target,
                os.path.join(os.path.dirname(os.path.abspath(path)),
                             os.path.basename(target)),
                os.path.join(os.path.dirname(os.path.abspath(path)), "..",
                             target),
            )
            target = next((c for c in candidates if os.path.exists(c)),
                          target)
        with open(target) as f:
            data = json.load(f)
    return data


def fps_metrics(run: dict) -> dict[tuple, float]:
    """{(benchmark, row-id, field): value} for every fps-like number."""
    out: dict[tuple, float] = {}
    for bench, payload in run.items():
        rows = payload.get("rows") if isinstance(payload, dict) else None
        if not isinstance(rows, list):
            continue
        for i, row in enumerate(rows):
            if not isinstance(row, dict):
                continue
            row_id = next((str(row[k]) for k in _ROW_KEYS if k in row),
                          str(i))
            for field in _FPS_FIELDS:
                v = row.get(field)
                if isinstance(v, (int, float)) and v > 0:
                    out[(bench, row_id, field)] = float(v)
    return out


def compare(baseline: dict, new: dict, max_drop: float) -> list[str]:
    """Regressions worse than ``max_drop``, as human-readable lines."""
    base_m, new_m = fps_metrics(baseline), fps_metrics(new)
    failures = []
    for key in sorted(base_m.keys() & new_m.keys()):
        b, n = base_m[key], new_m[key]
        drop = 1.0 - n / b
        if drop > max_drop:
            failures.append(
                f"{'/'.join(key)}: {b:.2f} -> {n:.2f} "
                f"({drop:.0%} drop > {max_drop:.0%} allowed)")
    return failures + check_floors(new_m)


def check_floors(new_m: dict[tuple, float]) -> list[str]:
    """Absolute-floor failures in the new run (see ``_FLOOR_FIELDS``)."""
    failures = []
    for key in sorted(new_m):
        floor = _FLOOR_FIELDS.get(key[2])
        if floor is not None and new_m[key] < floor:
            failures.append(f"{'/'.join(key)}: {new_m[key]:.3f} below "
                            f"absolute floor {floor:.2f}")
    return failures


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--baseline", required=True,
                        help="baseline run (dump or latest.json pointer)")
    parser.add_argument("--new", required=True,
                        help="fresh run (dump or latest.json pointer)")
    parser.add_argument("--max-drop", type=float, default=0.20,
                        help="max tolerated relative fps drop (default 0.20)")
    args = parser.parse_args(argv)

    baseline, new = load_run(args.baseline), load_run(args.new)
    base_m, new_m = fps_metrics(baseline), fps_metrics(new)
    shared = base_m.keys() & new_m.keys()
    print(f"comparing {len(shared)} shared fps metrics "
          f"({len(base_m)} baseline, {len(new_m)} new)")
    if base_m and not shared:
        # a rename/row-shape drift that empties the intersection would
        # otherwise pass vacuously — a silently disabled gate is itself
        # a regression
        print("REGRESSION GATE BROKEN: baseline has fps metrics but the "
              "new run shares none (benchmark renamed or rows "
              "restructured?)")
        return 1
    failures = compare(baseline, new, args.max_drop)
    if failures:
        print("REGRESSION:")
        for line in failures:
            print(f"  {line}")
        return 1
    print(f"ok: no simulated-fps drop exceeds {args.max_drop:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
