"""One benchmark per paper table/figure (Synergy, 2018).

Each function returns (rows, derived_summary).  The DES (calibrated in
repro.core.clusters) reproduces the paper's runtime; see EXPERIMENTS.md
§Paper-validation for measured-vs-paper numbers.
"""

from __future__ import annotations

import statistics
import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.paper_cnns import PAPER_CNNS                 # noqa: E402
from repro.core.clusters import (Cluster, F_PE, NEON, S_PE,     # noqa: E402
                                 default_synergy_clusters)
from repro.core.scheduler import (search_sc, simulate,          # noqa: E402
                                  single_thread_latency)
from repro.models.cnn import build_simnet, cnn_flops_per_frame  # noqa: E402

FRAMES = 96

# power model from the paper's measurements (§4.1)
P_SYNERGY_W = 2.08
P_CPU_W = 1.52


def _nets():
    return {name: build_simnet(cfg) for name, cfg in PAPER_CNNS.items()}


def fig9_throughput():
    """Fig 9: Synergy throughput speedup over single-threaded Darknet."""
    rows = []
    for name, net in _nets().items():
        st = single_thread_latency(net)
        ws = simulate(net, policy="ws", frames=FRAMES)
        rows.append({"net": name, "fps": ws.fps, "single_thread_ms": st * 1e3,
                     "speedup": ws.fps * st})
    mean = statistics.mean(r["speedup"] for r in rows)
    return rows, {"mean_speedup": mean, "paper": 7.3}


def _config_only(n_fpe=0, n_spe=0, n_neon=0):
    accs = ([F_PE(i) for i in range(n_fpe)] + [S_PE(i) for i in range(n_spe)]
            + [NEON(i) for i in range(n_neon)])
    return [Cluster("only", tuple(accs))]


def fig11_latency_heterogeneity():
    """Fig 11: non-pipelined latency — CPU+NEON vs CPU+FPGA vs CPU+Het.

    The paper's non-pipelined designs are single-threaded hosts driving the
    whole accelerator pool, so each config is ONE cluster (a two-cluster
    split would add a slow-NEON straggler tail that the paper's setup does
    not have)."""
    rows = []
    for name, net in _nets().items():
        neon = simulate(net, _config_only(n_neon=2), policy="ws",
                        frames=24, pipelined=False)
        fpga = simulate(net, _config_only(n_fpe=6, n_spe=2), policy="ws",
                        frames=24, pipelined=False)
        het = simulate(net, _config_only(n_fpe=6, n_spe=2, n_neon=2),
                       policy="ws", frames=24, pipelined=False)
        rows.append({"net": name, "neon_ms": neon.latency_s * 1e3,
                     "fpga_ms": fpga.latency_s * 1e3,
                     "het_ms": het.latency_s * 1e3,
                     "het_vs_fpga": fpga.latency_s / het.latency_s - 1})
    mean = statistics.mean(r["het_vs_fpga"] for r in rows)
    return rows, {"mean_het_latency_gain": mean, "paper": 0.12}


def fig12_throughput_heterogeneity():
    """Fig 12: pipelined throughput — same comparison."""
    rows = []
    for name, net in _nets().items():
        fpga = simulate(net, _config_only(n_fpe=6, n_spe=2), policy="ws",
                        frames=FRAMES)
        het = simulate(net, default_synergy_clusters(), policy="ws",
                       frames=FRAMES)
        rows.append({"net": name, "fpga_fps": fpga.fps, "het_fps": het.fps,
                     "het_vs_fpga": het.fps / fpga.fps - 1})
    mean = statistics.mean(r["het_vs_fpga"] for r in rows)
    return rows, {"mean_het_throughput_gain": mean, "paper": 0.15}


def fig13_work_stealing():
    """Fig 13: WS vs static-fixed (SF) vs static-custom (SC)."""
    rows = []
    for name, net in _nets().items():
        sf = simulate(net, policy="sf", frames=FRAMES)
        _, _, sc = search_sc(net, frames=64)
        ws = simulate(net, policy="ws", frames=FRAMES)
        rows.append({"net": name, "sf_fps": sf.fps, "sc_fps": sc.fps,
                     "ws_fps": ws.fps,
                     "ws_vs_sf": ws.fps / sf.fps - 1,
                     "ws_vs_sc": ws.fps / sc.fps - 1})
    return rows, {
        "mean_ws_vs_sf": statistics.mean(r["ws_vs_sf"] for r in rows),
        "mean_ws_vs_sc": statistics.mean(r["ws_vs_sc"] for r in rows),
        "paper": {"ws_vs_sf": 0.24, "ws_vs_sc": 0.06}}


def fig14_cluster_balance():
    """Fig 14: per-cluster busy time per frame, SF vs WS (CIFAR_Alex)."""
    net = build_simnet(PAPER_CNNS["CIFAR_Alex"])
    sf = simulate(net, policy="sf", frames=FRAMES)
    ws = simulate(net, policy="ws", frames=FRAMES)
    imb = lambda d: max(d.values()) / max(min(d.values()), 1e-9)
    rows = [{"design": "SF", **{k: v * 1e3 for k, v in
                                sf.per_cluster_runtime.items()}},
            {"design": "Synergy", **{k: v * 1e3 for k, v in
                                     ws.per_cluster_runtime.items()}}]
    return rows, {"sf_imbalance": imb(sf.per_cluster_runtime),
                  "ws_imbalance": imb(ws.per_cluster_runtime),
                  "paper": {"sf": 24.3 / 12.3, "ws": 22.2 / 20.9}}


def table6_utilization():
    """Table 6: accelerator cluster utilization across designs."""
    rows = []
    for name, net in _nets().items():
        np_ = simulate(net, policy="ws", frames=24, pipelined=False)
        sf = simulate(net, policy="sf", frames=FRAMES)
        _, _, sc = search_sc(net, frames=64)
        ws = simulate(net, policy="ws", frames=FRAMES)
        rows.append({"net": name, "non_pipelined": np_.utilization,
                     "sf": sf.utilization, "sc": sc.utilization,
                     "synergy": ws.utilization})
    mean = {k: statistics.mean(r[k] for r in rows)
            for k in ("non_pipelined", "sf", "sc", "synergy")}
    return rows, {"mean": mean,
                  "paper": {"non_pipelined": 0.5605, "sf": 0.9246,
                            "sc": 0.9647, "synergy": 0.9980}}


def fig7_mmu_contention():
    """Fig 7: single-MMU vs multi-MMU scaling (queueing model analog).

    A PE's job has a memory phase (tile fetch/writeback through the MMU)
    and a compute phase.  With ONE MMU the memory phases serialize across
    PEs; with one MMU per 2 PEs they only pairwise serialize — per-job
    service time grows as max(compute, contenders * mem)."""
    mem_frac, comp_frac = 0.35, 0.65
    rows = []
    for n_pe in range(1, 9):
        single = n_pe / max(comp_frac, n_pe * mem_frac)
        multi = n_pe / max(comp_frac, 2 * mem_frac)
        rows.append({"n_pe": n_pe, "single_mmu_speedup": single,
                     "multi_mmu_speedup": multi})
    return rows, {"single_mmu_saturates_at": max(
        r["single_mmu_speedup"] for r in rows),
        "multi_mmu_linear": rows[-1]["multi_mmu_speedup"] > 6.5}


def table3_4_energy():
    """Tables 3/4: energy per frame and GOPS/W (power-model proxy:
    measured board powers from the paper x simulated frame times)."""
    rows = []
    for name, cfg in PAPER_CNNS.items():
        net = build_simnet(cfg)
        st = single_thread_latency(net)
        ws = simulate(net, policy="ws", frames=FRAMES)
        flops = cnn_flops_per_frame(cfg)
        e_orig = P_CPU_W * st * 1e3                  # mJ/frame
        e_syn = P_SYNERGY_W / ws.fps * 1e3
        rows.append({"net": name, "orig_mj": e_orig, "synergy_mj": e_syn,
                     "reduction": 1 - e_syn / e_orig,
                     "orig_gops_w": flops / st / P_CPU_W / 1e9,
                     "syn_gops_w": flops * ws.fps / P_SYNERGY_W / 1e9,
                     "fps": ws.fps})
    mean_red = statistics.mean(r["reduction"] for r in rows)
    return rows, {"mean_energy_reduction": mean_red, "paper": 0.8013}


def runtime_steal():
    """Live-runtime mirror of the Fig 13 / Table 6 claim: a steady-frame
    ThreadedPipeline through runtime_scope() on >=2 simulated PEs shows
    nonzero steals and a higher aggregate busy fraction than the same
    workload pinned single-engine (the acceptance metric of the runtime
    PR, on REAL threads instead of the DES)."""
    import jax
    import jax.numpy as jnp

    from repro.core.pipeline import EngineStage, ThreadedPipeline
    from repro.engines import get_engine
    from repro.soc import SynergyRuntime

    pool = ["F-PE", "S-PE"]
    engines = [get_engine(n) for n in pool]
    w = jax.random.normal(jax.random.key(0), (64, 48))
    frames = [jax.random.normal(jax.random.key(i), (320, 64))
              for i in range(8)]
    stages = [EngineStage.gemm("mm", w, engine="F-PE", tile=(32, 32, 32)),
              ("post", lambda y: float(jnp.sum(y)))]

    def busy_frac(before, after):
        d = [a.busy_s - b.busy_s for b, a in zip(before, after)]
        return sum(d) / (len(d) * max(d)) if max(d) > 0 else 0.0

    snap = lambda: [e.telemetry.snapshot() for e in engines]
    b0 = snap()
    _, pinned = ThreadedPipeline(stages).run(frames)
    pinned_frac = busy_frac(b0, snap())
    with SynergyRuntime(pool, name="bench") as rt, rt.scope():
        b1 = snap()
        _, st = ThreadedPipeline(stages).run(frames)
        rt_frac = busy_frac(b1, snap())
    rstats = st["runtime"]
    rows = [{"mode": "pinned(F-PE)", "fps": pinned["fps"],
             "busy_fraction": pinned_frac, "steals": 0},
            {"mode": "runtime(F-PE+S-PE)", "fps": st["fps"],
             "busy_fraction": rt_frac,
             "steals": rstats["total_steals"],
             "per_engine": {k: v["jobs"]
                            for k, v in rstats["engines"].items()}}]
    return rows, {
        "steals": rstats["total_steals"],
        "busy_fraction_pinned": round(pinned_frac, 3),
        "busy_fraction_runtime": round(rt_frac, 3),
        "runtime_beats_pinned": rt_frac > pinned_frac,
    }


def quant_pool():
    """Heterogeneous precision zoo (ISSUE 3): a mixed fp32+int8+VPU pool
    must beat the BEST homogeneous (single-precision-class) pool on
    busy-fraction-weighted simulated fps, with the int8 engine's decode
    outputs inside its calibrated tolerance of the fp32 oracle.

    The pool is one chip's worth of engines: the full-precision tile PE,
    its int8 weight-only twin (4x calibrated rate — weight bandwidth), and
    the VPU/NEON vector engine at the paper's 0.42x F-PE calibration.
    Virtual-time SimRuntime (the DES-conformant twin) supplies makespans,
    so the numbers are cost-model truth, not host-machine noise."""
    import jax

    from repro.core.job import JobSet
    from repro.engines.sim import SIM_ENGINE_SPECS, SimPEEngine
    from repro.engines.vpu import NeonVpuEngine
    from repro.quant import QuantizedEngine, calibrate, rel_err
    from repro.soc import SimRuntime

    fp32 = SimPEEngine("zoo-fp32", SIM_ENGINE_SPECS["F-PE"])
    int8 = QuantizedEngine(fp32, name="zoo-int8")
    vpu = NeonVpuEngine("zoo-vpu", interpret=True,
                        cost=SIM_ENGINE_SPECS["NEON"])
    report = calibrate(int8, tol=0.05)

    n_frames = min(FRAMES, 16)
    frames = [JobSet.for_gemm(i, 128, 256, 64, 32, name=f"decode{i}")
              for i in range(n_frames)]

    def run_pool(engines):
        makespan, fracs = 0.0, []
        for js in frames:
            res = SimRuntime(engines).run(js)
            makespan += res.makespan_s
            fracs.append(res.aggregate_busy_fraction)
        fps = len(frames) / makespan
        frac = statistics.mean(fracs)
        return {"fps": fps, "busy_fraction": frac,
                "weighted_fps": fps * frac}

    pools = {"fp32-only": [fp32], "int8-only": [int8], "vpu-only": [vpu],
             "mixed": [fp32, int8, vpu]}
    rows = [{"pool": name, **run_pool(engines)}
            for name, engines in pools.items()]
    by_name = {r["pool"]: r for r in rows}
    best_homog = max((r for r in rows if r["pool"] != "mixed"),
                     key=lambda r: r["weighted_fps"])

    # decode-accuracy leg: one real decode GEMM through the int8 engine,
    # measured with the SAME formula the calibration gate uses
    ka, kb = jax.random.split(jax.random.key(0))
    a = jax.random.normal(ka, (4, 64))
    w = jax.random.normal(kb, (64, 256)) * 0.05
    rel = rel_err(int8.execute(a, w), fp32.execute(a, w))

    return rows, {
        "mixed_vs_best_homogeneous":
            by_name["mixed"]["weighted_fps"] / best_homog["weighted_fps"],
        "best_homogeneous": best_homog["pool"],
        "mixed_wins":
            by_name["mixed"]["weighted_fps"] > best_homog["weighted_fps"],
        "int8_decode_rel_err": rel,
        "int8_tol": report.tol,
        "int8_within_tol": rel <= report.tol,
    }


def qmm_int8x8():
    """ISSUE 4 acceptance: the TRUE int8×int8 path vs the weight-only
    fp32-cast dot it replaces, on wall time and rel-error.

    Two legs, each over decode-shaped GEMMs (k = d_model, n = 4*d_model
    of the reduced serving configs; m = 1 single-token plus small decode
    batches):

      * **pallas-pe** (the headline): the quantized tile PE vs the fp32
        tile PE under the SAME executor — the qmm kernel against
        ``tiled_mm`` over fp32-cast weights followed by the unfused
        dequant tail (the weight-only PE cannot fuse the full-width (n,)
        scale — that separate pass is part of its real cost).  Off-TPU
        both kernels run through the Pallas interpreter; on TPU both run
        native (Mosaic), where the int8 MXU mode is the whole point.
      * **xla-dot**: the same two paths on the raw XLA backend.  Honest
        caveat, visible in the rows: XLA *CPU* ships no vectorized int8
        GEMM, so off-TPU this leg hovers near parity — the bandwidth win
        the kernel is built for needs hardware with an int8 datapath.

    The derived block also reports the measured int8 MAC rate — the
    number that replaces the simulated 4x in the QuantizedEngine cost
    model (``register_quantized`` / runtime recalibration persist it)."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.kernels.qmm import qmm_matmul
    from repro.kernels.tiled_mm import tiled_matmul
    from repro.quant import (DEFAULT_TOL, dequant_finish, quant_gemm,
                             quantize_weights, rel_err)
    from repro.quant.act import one_shot_act_scale, quantize_activations

    smoke = FRAMES < 96
    rounds = 3 if smoke else 7
    interpret = jax.default_backend() != "tpu"
    shapes = [(1, 256, 1024), (8, 256, 1024), (32, 256, 512)]
    rows = []
    for m, k, n in shapes:
        ka, kb = jax.random.split(jax.random.key(0))
        a = jax.random.normal(ka, (m, k))
        w = jax.random.normal(kb, (k, n)) * 0.05
        qw = quantize_weights(w)
        act_scale = one_shot_act_scale(a)
        a_q = quantize_activations(a, act_scale)
        ref = jnp.dot(a, w)

        def pe_weight_only(a=a, qw=qw):
            acc = tiled_matmul(a, qw.q.astype(jnp.float32), tile=32,
                               interpret=interpret, out_dtype=jnp.float32)
            return dequant_finish(acc, qw, out_dtype=jnp.float32)

        def pe_int8x8(a_q=a_q, qw=qw, s=act_scale):
            return qmm_matmul(a_q, qw.q, qw.scale, act_scale=s,
                              tile=(32, 32, 32), interpret=interpret)

        xla_weight_only = jax.jit(lambda a, qw=qw: quant_gemm(a, qw))
        xla_int8x8 = jax.jit(lambda a, qw=qw, s=act_scale:
                             quant_gemm(a, qw, act_scale=s))

        def median_wall(fn, *args):
            jax.block_until_ready(fn(*args))      # compile outside timing
            walls = []
            for _ in range(rounds):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(*args))
                walls.append(time.perf_counter() - t0)
            return statistics.median(walls)

        for leg, fn_wo, fn_q8, out_q8 in (
                ("pallas-pe", median_wall(pe_weight_only),
                 median_wall(pe_int8x8), pe_int8x8()),
                ("xla-dot", median_wall(xla_weight_only, a),
                 median_wall(xla_int8x8, a), xla_int8x8(a))):
            rows.append({
                "leg": f"{leg} {m}x{k}x{n}",
                "fp32_dot_us": fn_wo * 1e6,
                "int8x8_us": fn_q8 * 1e6,
                "speedup": fn_wo / fn_q8,
                "rel_err_int8x8": rel_err(out_q8, ref),
                "int8_macs_per_s": m * k * n / fn_q8,
            })
    pe = [r for r in rows if r["leg"].startswith("pallas-pe")]
    xla = [r for r in rows if r["leg"].startswith("xla-dot")]
    max_rel = max(r["rel_err_int8x8"] for r in rows)
    return rows, {
        # headline: the quantized tile PE vs the fp32 tile PE
        "pe_int8_speedup_median": statistics.median(
            [r["speedup"] for r in pe]),
        "int8_beats_fp32_dot": all(r["speedup"] > 1.0 for r in pe),
        "xla_dot_int8_speedup_median": statistics.median(
            [r["speedup"] for r in xla]),
        "max_rel_err": max_rel,
        "tol": DEFAULT_TOL,
        "within_tol": max_rel <= DEFAULT_TOL,
        "measured_int8_macs_per_s": statistics.median(
            [r["int8_macs_per_s"] for r in xla]),
    }


def serve_throughput():
    """ISSUE 5 acceptance: continuous batching + async in-flight
    submissions vs the legacy one-request-per-step server.

    Both servers run the SAME workload through a live SynergyRuntime over
    the paper's calibrated F-PE/S-PE/NEON sim engines: real conv-as-GEMM
    prefill (batched im2col), real coalesced decode GEMM submissions.
    The BASELINE admits one request per step, submits one decode GEMM per
    live slot, and reaps synchronously (``max_inflight=0``); the batched
    mode admits a full wave per step, coalesces the live slots into one
    submission, and overlaps an in-flight window of 4.

    Metrics: wall tokens/s and requests/s per mode (machine-dependent —
    reported but NOT gated), and ``tokens_per_s_rel`` — each mode's
    tokens/s relative to the per-request baseline of the SAME run, the
    machine-stable ratio ``check_regression.py`` gates (>20% drop fails).
    The conv front-end is a reduced MNIST-topology net so host compute
    does not swamp the dispatch-overhead signal the benchmark measures —
    the same reduced-config convention every serving test uses."""
    import time

    import jax

    from repro.configs import ARCHS, reduced
    from repro.core.serving import Request, SynergyServer
    from repro.models import init_model
    from repro.models.cnn import CNNConfig
    from repro.soc import SynergyRuntime

    cfg = reduced(ARCHS["granite-3-2b"], n_layers=2, d_model=32,
                  n_heads=2, d_ff=64, vocab=128)
    params = init_model(cfg, jax.random.key(0))
    # serving-scale tile (256 rows/panel): the batched wave's 4096-row
    # conv GEMM splits into a handful of full panels, while the
    # per-request baseline pays per-chain panel rounding — exactly the
    # dispatch amortization the row-panel split exists for (the paper's
    # TS=32 stays the default elsewhere; tile choice is a serving knob)
    tiny_cnn = CNNConfig(
        name="MNIST-r8", input_hw=8, cin=1, tile=256, layers=(
            ("conv", 8, 3, 1, 1), ("pool", 2),
            ("conv", 16, 3, 1, 1), ("pool", 2), ("fc", 10)))
    # the workload is deliberately NOT shrunk under --smoke: the gated
    # tokens_per_s_rel ratio must come from the same request mix in the
    # committed baseline and the CI smoke run (the whole benchmark is a
    # few seconds), or the gate would compare different workloads
    n_req, reps = 24, 3
    slots, new_tokens, plen = 8, 8, 8

    def requests(base):
        return [Request(base + i,
                        jax.random.randint(jax.random.key(i), (plen,), 0,
                                           128),
                        max_new_tokens=new_tokens) for i in range(n_req)]

    def make_server(rt, admission, decode_mode, max_inflight):
        srv = SynergyServer(cfg, params, slots=slots, max_len=32,
                            prefill_len=plen, runtime=rt,
                            prefill_cnn=tiny_cnn, admission=admission,
                            decode_mode=decode_mode,
                            max_inflight=max_inflight)
        for r in requests(0):              # warmup: jit compiles
            srv.submit(r)
        srv.run()
        return srv

    def measure(srv, rep):
        srv.reset_stats()
        for r in requests((rep + 1) * 1000):
            srv.submit(r)
        t0 = time.perf_counter()
        stats = srv.run()
        dt = time.perf_counter() - t0
        return stats.tokens_out / dt, stats.prefills / dt, stats

    # the two modes are measured back-to-back INSIDE each repetition and
    # compared as per-rep ratios: host drift (compile threads, cgroup
    # neighbors) hits both legs of a rep alike, so the median ratio is
    # far more stable than a ratio of independently-measured medians
    with SynergyRuntime(["F-PE", "S-PE", "NEON"], name="serve-base") as rt0, \
            SynergyRuntime(["F-PE", "S-PE", "NEON"],
                           name="serve-batched") as rt1:
        base_srv = make_server(rt0, "single", "per-slot", 0)
        bat_srv = make_server(rt1, "wave", "batched", 4)
        base_samples, bat_samples, ratios = [], [], []
        for rep in range(reps):
            b_tok, b_req, base_stats = measure(base_srv, rep)
            a_tok, a_req, bat_stats = measure(bat_srv, rep)
            base_samples.append((b_tok, b_req))
            bat_samples.append((a_tok, a_req))
            ratios.append(a_tok / b_tok)
    med = lambda xs: statistics.median(xs)   # per-field, not paired-tuple
    base_tok, base_req = (med([s[0] for s in base_samples]),
                          med([s[1] for s in base_samples]))
    bat_tok, bat_req = (med([s[0] for s in bat_samples]),
                        med([s[1] for s in bat_samples]))
    speedup = statistics.median(ratios)
    rows = [
        {"mode": "per-request", "tokens_per_s_wall": base_tok,
         "requests_per_s_wall": base_req, "tokens_per_s_rel": 1.0,
         "prefill_waves": base_stats.prefill_waves,
         "runtime_jobs": base_stats.runtime_jobs,
         "inflight_peak": base_stats.inflight_peak},
        {"mode": "batched-async", "tokens_per_s_wall": bat_tok,
         "requests_per_s_wall": bat_req,
         "tokens_per_s_rel": speedup,
         "prefill_waves": bat_stats.prefill_waves,
         "runtime_jobs": bat_stats.runtime_jobs,
         "inflight_peak": bat_stats.inflight_peak},
    ]
    return rows, {
        "batched_speedup_tokens_per_s": speedup,
        "batched_speedup_requests_per_s": bat_req / base_req,
        "meets_2x": speedup >= 2.0,
        "baseline_tokens_per_s": base_tok,
        "batched_tokens_per_s": bat_tok,
        "prefill_waves": {"per-request": base_stats.prefill_waves,
                          "batched": bat_stats.prefill_waves},
    }


def graph_overlap():
    """ISSUE 6 acceptance: dataflow-graph prefill vs the serialized
    conv-chain, plus chunked prefill's decode tail latency.

    Leg 1 (``prefill_overlap_rel``, gated >= 1.3x): the same conv
    prefill waves on a 2-engine pool, (a) as the PR-5-style CHAIN —
    gather, submit the layer GEMM, block on its result, gather the next
    layer, one wave at a time — vs (b) as ``submit_graph`` DAGs, all
    waves in flight at once: layer l+1's im2col gather runs on the host
    executor WHILE layer l's panels execute on the workers, and
    independent waves fill both engines.  The pool uses PACED engines
    whose ``execute`` sleeps out the MAC-rate cost model before the real
    math — the wall-clock analog of the DES PE timing (``time.sleep``
    releases the GIL, so measured overlap is genuine engine-busy
    overlap, not Python scheduling noise).  Each wave's conv GEMMs are
    single row panels (m <= tile), the regime the paper's dataflow
    pipelining targets: one layer alone cannot fill the pool, only
    cross-wave/cross-layer concurrency can.  Measured back-to-back
    inside each repetition; the gated number is the median per-rep fps
    ratio.

    Leg 2 (``decode_p99_rel``, gated): one request trace through
    ``SynergyServer`` with blocking admission vs ``prefill_chunk_macs``
    chunking, recording the wall-clock gap between consecutive decode
    advances.  Blocking admission stalls live decoders for a whole wave
    (conv graph + full LM replay) — its p99 inter-decode gap balloons;
    chunked prefill bounds it.  The gated ratio is
    ``p99_blocking / p99_chunked`` (> 1 means chunking improves the
    decode tail), medianed over repetitions."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.configs import ARCHS, reduced
    from repro.core.im2col import im2col_wave
    from repro.core.serving import Request, SynergyServer
    from repro.engines import CAP_GEMM, CostModel, Engine
    from repro.models import init_model
    from repro.models.cnn import (CNNConfig, conv_graph_steps, conv_jobsets,
                                  conv_wave_graph, init_cnn, maxpool2d)
    from repro.soc import SynergyRuntime

    class _PacedEngine(Engine):
        """Sleeps out the cost model's busy time, then runs the real
        math — an F-PE whose MAC rate is enforced on the wall clock."""

        def __init__(self, name, macs_per_s):
            super().__init__(name, {CAP_GEMM, "epilogue"},
                             cost=CostModel(macs_per_s=macs_per_s))
            self._macs_per_s = macs_per_s

        def execute(self, a, b, *, bias=None, activation=None, tile=None,
                    out_dtype=None, precision=None):
            m, k = a.shape
            time.sleep(m * k * b.shape[1] / self._macs_per_s)
            y = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
            if bias is not None:
                y = y + bias
            if activation is not None:
                y = activation(y)
            return y.astype(out_dtype or a.dtype)

    cnn = CNNConfig(
        name="MNIST-r8", input_hw=8, cin=1, tile=256, layers=(
            ("conv", 8, 3, 1, 1), ("pool", 2),
            ("conv", 16, 3, 1, 1), ("pool", 2), ("fc", 10)))
    cnn_params = init_cnn(cnn, jax.random.key(0))
    steps = conv_graph_steps(cnn)
    # like serve_throughput, the workload is NOT shrunk under --smoke:
    # the gated ratios must come from the same work mix as the baseline.
    # n_frames=4 keeps every conv GEMM a single <=256-row panel.
    n_frames, waves, reps = 4, 8, 5
    pace_macs_per_s = 4e6
    frames = [jax.random.normal(jax.random.key(100 + w),
                                (n_frames, cnn.input_hw, cnn.input_hw,
                                 cnn.cin)) for w in range(waves)]

    def wave_jobsets(w):
        return [js for _, js in
                conv_jobsets(cnn, n_frames, name_prefix=f"w{w}/")]

    def run_chain(rt):
        t0 = time.perf_counter()
        for w in range(waves):
            x = frames[w]
            for (i, pools, (k, s, p), (oh, ow, cout)), js in zip(
                    steps, wave_jobsets(w)):
                for size in pools:
                    x = maxpool2d(x, size)
                a = im2col_wave(x, k, k, s, p)
                y = rt.submit_gemm(
                    a, cnn_params[f"conv{i}_w"].reshape(-1, cout),
                    jobset=js, bias=cnn_params[f"conv{i}_b"],
                    activation=jax.nn.relu, tile=(js.ts_m, js.ts_n, js.ts_k),
                    job_class="prefill").result(240)
                x = y.reshape(n_frames, oh, ow, cout)
        return waves * n_frames / (time.perf_counter() - t0)

    def run_graph(rt):
        t0 = time.perf_counter()
        futs = []
        for w in range(waves):
            nodes, edges = conv_wave_graph(cnn, cnn_params, frames[w],
                                           steps, wave_jobsets(w), n_frames)
            futs.append(rt.submit_graph(nodes, edges, name=f"wave{w}"))
        for gf in futs:
            gf.result(240)
        return waves * n_frames / (time.perf_counter() - t0)

    def paced_pool():
        return [_PacedEngine("paced-a", pace_macs_per_s),
                _PacedEngine("paced-b", pace_macs_per_s)]

    with SynergyRuntime(paced_pool(), name="ovl-chain") as rt_c, \
            SynergyRuntime(paced_pool(), name="ovl-graph") as rt_g:
        run_chain(rt_c)                     # warmup: jit compiles
        run_graph(rt_g)
        chain_fps, graph_fps, ratios = [], [], []
        for _ in range(reps):
            c = run_chain(rt_c)
            g = run_graph(rt_g)
            chain_fps.append(c)
            graph_fps.append(g)
            ratios.append(g / c)
    overlap_rel = statistics.median(ratios)

    # ---- leg 2: decode tail latency under concurrent prefill ----------
    cfg = reduced(ARCHS["granite-3-2b"], n_layers=2, d_model=32,
                  n_heads=2, d_ff=64, vocab=128)
    params = init_model(cfg, jax.random.key(0))
    # plen=32: the blocking wave's synchronous LM replay (32 tokens in
    # one admission) towers over a single decode step, which is what
    # chunking amortizes; n_req=32 gives enough decode gaps per rep for
    # a stable p99
    n_req, slots, plen = 32, 4, 32

    def requests(base):
        # staggered lengths: slots free at DIFFERENT times, so blocking
        # wave admission lands while other decoders are still live
        return [Request(base + i,
                        jax.random.randint(jax.random.key(i), (plen,), 0,
                                           128),
                        max_new_tokens=4 + (i % 9)) for i in range(n_req)]

    def make_server(rt, chunk):
        srv = SynergyServer(cfg, params, slots=slots, max_len=64,
                            prefill_len=plen, runtime=rt, prefill_cnn=cnn,
                            max_inflight=4, prefill_chunk_macs=chunk)
        for r in requests(0):              # warmup: jit compiles
            srv.submit(r)
        srv.run()
        return srv

    def p99_decode_gap(srv, rep):
        stamps = []
        orig = srv._do_decode

        def timed():
            orig()
            stamps.append(time.perf_counter())

        srv._do_decode = timed
        try:
            srv.reset_stats()
            for r in requests((rep + 1) * 1000):
                srv.submit(r)
            stats = srv.run()
        finally:
            srv._do_decode = orig
        gaps = sorted(b - a for a, b in zip(stamps, stamps[1:]))
        return gaps[int(0.99 * (len(gaps) - 1))], stats

    # ~1-token LM-replay quanta + one conv jobset per chunk at this cfg
    chunk_macs = 16_384
    with SynergyRuntime(["F-PE", "S-PE"], name="p99-blk") as rt_b, \
            SynergyRuntime(["F-PE", "S-PE"], name="p99-chk") as rt_k:
        blk_srv = make_server(rt_b, None)
        chk_srv = make_server(rt_k, chunk_macs)
        blk_p99s, chk_p99s, p99_ratios = [], [], []
        for rep in range(reps):
            b99, blk_stats = p99_decode_gap(blk_srv, rep)
            c99, chk_stats = p99_decode_gap(chk_srv, rep)
            blk_p99s.append(b99)
            chk_p99s.append(c99)
            p99_ratios.append(b99 / c99)
    p99_rel = statistics.median(p99_ratios)

    rows = [
        {"mode": "conv-chain", "fps_wall": statistics.median(chain_fps),
         "prefill_overlap_rel": 1.0},
        {"mode": "graph", "fps_wall": statistics.median(graph_fps),
         "prefill_overlap_rel": overlap_rel},
        {"mode": "blocking-admission",
         "decode_p99_gap_s_wall": statistics.median(blk_p99s),
         "decode_stall_steps": blk_stats.decode_stall_steps,
         "decode_p99_rel": 1.0},
        {"mode": "chunked-prefill",
         "decode_p99_gap_s_wall": statistics.median(chk_p99s),
         "decode_stall_steps": chk_stats.decode_stall_steps,
         "prefill_chunks": chk_stats.prefill_chunks,
         "prefill_chunk_macs": chunk_macs,
         "decode_p99_rel": p99_rel},
    ]
    return rows, {
        "prefill_overlap_rel": overlap_rel,
        "meets_1_3x": overlap_rel >= 1.3,
        "chain_fps_wall": statistics.median(chain_fps),
        "graph_fps_wall": statistics.median(graph_fps),
        "decode_p99_rel": p99_rel,
        "chunked_improves_p99": p99_rel > 1.0,
        "blocking_decode_stall_steps": blk_stats.decode_stall_steps,
        "chunked_decode_stall_steps": chk_stats.decode_stall_steps,
    }


def qos_slo():
    """ISSUE 7 acceptance: multi-tenant QoS under overload, plus
    self-healing pool recovery.

    Leg 1 (``slo_attainment_rel``, gated >= 1.5x): an overloaded
    2-tenant request mix — a BULK flood submitted ahead of a small GOLD
    (interactive, deadlined) stream — served on a PACED 2-engine pool,
    (a) by the untenanted FIFO server and (b) by the QoS server (gold:
    priority 10, weight 4, tenant-class deadline; bulk: sheddable, no
    deadline).  FIFO admits in arrival order, so every gold request
    waits behind the whole flood; QoS admission picks gold first and its
    prefill/decode panels carry priority tags through the runtime.  The
    gold deadline is SELF-CALIBRATED each run (2.5x the measured solo
    gold makespan on the same warmed pool, +0.25 s timer floor), so the
    attainment gap measures scheduling policy, not host speed.  The
    gated number is the median per-rep ratio of gold deadline
    attainment, with the FIFO denominator floored at one hit so a
    total-miss baseline cannot divide by zero.

    Leg 2 (``recovery_fps_rel``, gated >= 0.8): a heterogeneous paced
    pool (two fast engines + one slow at 1/4 rate) runs GEMM waves
    (a) healthy, (b) with the slow engine GRINDING at 12x its calibrated
    cost (health checks off — stragglers gate every wave), and (c) with
    the self-healing policy on: the runtime notices the rate collapse,
    quarantines the sick engine, drains its queue to the survivors, and
    steady-state throughput is measured AFTER the quarantine event.  The
    sick engine contributes 1/9 of pool capacity, so full recovery is
    ~0.89x healthy fps — gated at >= 0.8; the grinding fps is reported
    alongside to show what quarantine buys.

    Like serve_throughput/graph_overlap, the workload is NOT shrunk
    under --smoke: the gated ratios must come from the same work mix as
    the committed baseline."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.configs import ARCHS, reduced
    from repro.core.job import JobSet
    from repro.core.serving import Request, SynergyServer
    from repro.engines import CAP_GEMM, CostModel, Engine
    from repro.models import init_model
    from repro.models.cnn import CNNConfig
    from repro.soc import HealthPolicy, SynergyRuntime, Tenant
    from repro.soc.qos import QosClass

    class _PacedEngine(Engine):
        """Sleeps out the cost model's busy time (x a mutable grind
        factor), then runs the real math."""

        def __init__(self, name, macs_per_s):
            super().__init__(name, {CAP_GEMM, "epilogue"},
                             cost=CostModel(macs_per_s=macs_per_s))
            self._macs_per_s = macs_per_s
            self.grind = 1.0          # >1: engine is sick

        def execute(self, a, b, *, bias=None, activation=None, tile=None,
                    out_dtype=None, precision=None):
            m, k = a.shape
            time.sleep(m * k * b.shape[1] / self._macs_per_s * self.grind)
            y = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
            if bias is not None:
                y = y + bias
            if activation is not None:
                y = activation(y)
            return y.astype(out_dtype or a.dtype)

    # ---- leg 1: FIFO vs QoS gold deadline attainment ------------------
    cnn = CNNConfig(
        name="MNIST-r8", input_hw=8, cin=1, tile=256, layers=(
            ("conv", 8, 3, 1, 1), ("pool", 2),
            ("conv", 16, 3, 1, 1), ("pool", 2), ("fc", 10)))
    cfg = reduced(ARCHS["granite-3-2b"], n_layers=2, d_model=32,
                  n_heads=2, d_ff=64, vocab=128)
    params = init_model(cfg, jax.random.key(0))
    n_gold, n_bulk, slots, plen, reps = 4, 12, 2, 8, 3
    pace = 1e6                      # paced time dominates host overhead

    def pool2():
        return [_PacedEngine("slo-a", pace), _PacedEngine("slo-b", pace)]

    def requests(base, n, tenant, max_new, deadline_s=None):
        return [Request(base + i,
                        jax.random.randint(jax.random.key(base + i),
                                           (plen,), 0, 128),
                        max_new_tokens=max_new, tenant=tenant,
                        deadline_s=deadline_s) for i in range(n)]

    def make_server(rt, tenants):
        srv = SynergyServer(cfg, params, slots=slots, max_len=32,
                            prefill_len=plen, runtime=rt, prefill_cnn=cnn,
                            tenants=tenants)
        warm = "gold" if tenants else None
        for r in requests(900_000, slots, warm, 2):   # warmup: jit
            srv.submit(r)
        srv.run()
        srv.reset_stats()
        return srv

    gold_attains = {"fifo": [], "qos": []}
    ratios = []
    with SynergyRuntime(pool2(), name="slo-fifo") as rt_f, \
            SynergyRuntime(pool2(), name="slo-qos") as rt_q:
        # gold tenant first: the calibration run needs it to exist
        gold_cls = QosClass("gold", priority=10, deadline_s=None,
                            weight=4.0)
        bulk_cls = QosClass("bulk", priority=-10, sheddable=True)
        qos_srv = make_server(rt_q, [Tenant("gold", gold_cls),
                                     Tenant("bulk", bulk_cls)])
        fifo_srv = make_server(rt_f, None)
        # self-calibrate the deadline: solo gold makespan on this host
        t0 = time.perf_counter()
        for r in requests(800_000, n_gold, "gold", 4):
            qos_srv.submit(r)
        qos_srv.run()
        solo_s = time.perf_counter() - t0
        deadline_s = 2.5 * solo_s + 0.25
        qos_srv.reset_stats()

        for rep in range(reps):
            base = (rep + 1) * 10_000
            # FIFO: bulk flood first, gold behind it, arrival order wins
            bulk_f = requests(base, n_bulk, None, 8)
            gold_f = requests(base + 5000, n_gold, None, 4,
                              deadline_s=deadline_s)
            for r in bulk_f + gold_f:
                fifo_srv.submit(r)
            fifo_srv.run()
            fifo_hits = sum(1 for r in gold_f if r.done_at is not None
                            and r.done_at <= r.deadline_at)
            # QoS: same arrival order; priority admission + tagged panels
            bulk_q = requests(base, n_bulk, "bulk", 8)
            gold_q = requests(base + 5000, n_gold, "gold", 4,
                              deadline_s=deadline_s)
            for r in bulk_q + gold_q:
                qos_srv.submit(r)
            qstats = qos_srv.run()
            qos_hits = qstats.tenants["gold"].deadline_hits
            qos_srv.reset_stats()
            gold_attains["fifo"].append(fifo_hits / n_gold)
            gold_attains["qos"].append(qos_hits / n_gold)
            ratios.append(qos_hits / max(fifo_hits, 1))
    slo_rel = statistics.median(ratios)

    # ---- leg 2: self-healing pool recovery ----------------------------
    fast, waves_t = 4e6, 16

    def pool3():
        return [_PacedEngine("heal-a", fast), _PacedEngine("heal-b", fast),
                _PacedEngine("heal-c", fast / 4)]

    def run_wave(rt, step):
        a = jnp.ones((128, 32)); b = jnp.ones((32, 32))
        futs = [rt.submit_gemm(
            a, b, jobset=JobSet.for_gemm(step * 3 + i, 128, 32, 32, 32,
                                         name=f"hw{step}/{i}"),
            tile=(32, 32, 32)) for i in range(3)]
        for f in futs:
            f.result(240)

    def timed_waves(rt, base, n=waves_t):
        t0 = time.perf_counter()
        for s in range(n):
            run_wave(rt, base + s)
        return n / (time.perf_counter() - t0)

    # probes disabled: the engine stays sick, readmission would only
    # re-introduce the straggler into the timed window
    heal = HealthPolicy(alpha=0.5, quarantine_below=0.5,
                        readmit_above=0.8, min_samples=3,
                        probe_interval_s=1e9, min_probe_samples=2)
    with SynergyRuntime(pool3(), name="heal-base") as rt:
        run_wave(rt, 990)                      # warmup: jit compiles
        healthy_fps = timed_waves(rt, 0)
    with SynergyRuntime(pool3(), name="heal-grind") as rt:
        rt.find_engine("heal-c").grind = 12.0
        grind_fps = timed_waves(rt, 100, n=6)
    with SynergyRuntime(pool3(), name="heal-heal", health=heal) as rt:
        for s in range(4):          # healthy EMA baseline, then degrade
            run_wave(rt, 190 + s)
        rt.find_engine("heal-c").grind = 12.0
        quarantined_after = None
        for s in range(40):                    # detection phase, untimed
            run_wave(rt, 200 + s)
            if rt.stats()["quarantines"] >= 1:
                quarantined_after = s + 1
                break
        recovered_fps = timed_waves(rt, 300)
    recovery_rel = recovered_fps / healthy_fps
    grind_rel = grind_fps / healthy_fps

    rows = [
        {"mode": "fifo", "gold_attainment": statistics.median(
            gold_attains["fifo"]), "slo_attainment_rel": 1.0},
        {"mode": "qos", "gold_attainment": statistics.median(
            gold_attains["qos"]), "gold_deadline_s": deadline_s,
         "slo_attainment_rel": slo_rel},
        {"mode": "pool-healthy", "fps_wall": healthy_fps,
         "recovery_fps_rel": 1.0},
        {"mode": "pool-grinding", "fps_wall": grind_fps,
         "grind_fps_rel": grind_rel},
        {"mode": "pool-quarantined", "fps_wall": recovered_fps,
         "quarantined_after_waves": quarantined_after,
         "recovery_fps_rel": recovery_rel},
    ]
    return rows, {
        "slo_attainment_rel": slo_rel,
        "meets_1_5x": slo_rel >= 1.5,
        "gold_deadline_s": deadline_s,
        "fifo_gold_attainment": statistics.median(gold_attains["fifo"]),
        "qos_gold_attainment": statistics.median(gold_attains["qos"]),
        "recovery_fps_rel": recovery_rel,
        "meets_0_8x_recovery": recovery_rel >= 0.8,
        "grind_fps_rel": grind_rel,
        "quarantined_after_waves": quarantined_after,
    }


def obs_overhead():
    """ISSUE 8 gate: the span tracer must cost <= 5% on the paced pool.

    The same GEMM-wave workload runs on a 3-engine PACED pool twice per
    rep — tracer off (the default no-tracer runtime: every emit site is
    one attribute check) and tracer on (a 1M-event ring recording seed /
    enqueue / dequeue / panel / steal events for every wave) — and the
    gated number is the median per-rep fps ratio ``traced / untraced``
    (``trace_overhead_rel``, floored at 0.95 in check_regression.py).
    Panels sleep out cost-model time like graph_overlap/qos_slo, so the
    ratio is machine-stable: the tracer's per-event cost is measured
    against realistic panel durations, not against a trivially fast
    in-cache GEMM.  Not shrunk under --smoke for the same reason as the
    other gated benchmarks."""
    import statistics
    import time

    import jax.numpy as jnp

    from repro.core.job import JobSet
    from repro.engines import CAP_GEMM, CostModel, Engine
    from repro.obs.trace import Tracer, trace_scope
    from repro.soc import SynergyRuntime

    pace = 4e6
    waves, reps = 8, 3

    class _PacedEngine(Engine):
        def __init__(self, name):
            super().__init__(name, {CAP_GEMM, "epilogue"},
                             cost=CostModel(macs_per_s=pace))

        def execute(self, a, b, *, bias=None, activation=None, tile=None,
                    out_dtype=None, precision=None):
            m, k = a.shape
            time.sleep(m * k * b.shape[1] / pace)
            y = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
            return y.astype(out_dtype or a.dtype)

    def pool():
        return [_PacedEngine("obs-a"), _PacedEngine("obs-b"),
                _PacedEngine("obs-c")]

    def run_wave(rt, step):
        a = jnp.ones((128, 32)); b = jnp.ones((32, 32))
        futs = [rt.submit_gemm(
            a, b, jobset=JobSet.for_gemm(step * 3 + i, 128, 32, 32, 32,
                                         name=f"ow{step}/{i}"),
            tile=(32, 32, 32)) for i in range(3)]
        for f in futs:
            f.result(240)

    def timed_fps(tracer, base):
        # trace_scope pins the process-default tracer for the leg: the
        # off leg must stay untraced (runtime fallback AND the dispatch
        # emit site read the default) even under `run.py --trace`
        with trace_scope(tracer), \
             SynergyRuntime(pool(), name="obs-bench",
                            tracer=tracer) as rt:
            run_wave(rt, base + 990)           # warmup: jit compiles
            t0 = time.perf_counter()
            for s in range(waves):
                run_wave(rt, base + s)
            return waves / (time.perf_counter() - t0)

    ratios, off_fps, on_fps, n_events = [], [], [], 0
    for rep in range(reps):
        f_off = timed_fps(None, rep * 1000)
        tracer = Tracer(capacity=1_000_000)
        f_on = timed_fps(tracer, rep * 1000 + 500)
        n_events = len(tracer.events())
        off_fps.append(f_off)
        on_fps.append(f_on)
        ratios.append(f_on / f_off)
    rel = statistics.median(ratios)
    rows = [{"mode": "tracer-off", "fps_wall": statistics.median(off_fps)},
            {"mode": "tracer-on", "fps_wall": statistics.median(on_fps),
             "trace_overhead_rel": rel, "events_per_leg": n_events}]
    return rows, {"trace_overhead_rel": round(rel, 4),
                  "events_per_leg": n_events}


def fault_recovery():
    """ISSUE 9 gate: a pool that loses an engine mid-run must keep at
    least 0.8x its clean throughput once recovery settles.

    A 3-engine PACED pool (two at ``fast``, one at ``fast/4`` — combined
    capacity 9 units) runs the GEMM-wave workload twice: a clean leg
    (full pool, no faults) and a fault leg where a deterministic
    FaultPlan KILLS the slow engine's worker mid-panel.  The heartbeat
    monitor declares the worker dead, its queued + in-flight panels
    re-seed onto the two survivors (capacity 8 units), and the timed
    window measures the recovered pool: ``fault_recovery_rel`` =
    recovered / clean fps, ideally ~8/9 = 0.89, floored at 0.8 in
    check_regression.py.  The detection phase (death through re-seed) is
    untimed, mirroring qos_slo's quarantine leg — the gate protects the
    steady recovered state, not the one wave that ate the heartbeat
    timeout.  Not shrunk under --smoke like the other gated benchmarks."""
    import time

    import jax.numpy as jnp

    from repro.core.job import JobSet
    from repro.engines import CAP_GEMM, CostModel, Engine
    from repro.soc import (FaultPlan, FaultSpec, RetryPolicy,
                           SynergyRuntime, wrap_pool)

    fast, waves = 4e6, 16

    class _PacedEngine(Engine):
        def __init__(self, name, macs_per_s):
            super().__init__(name, {CAP_GEMM, "epilogue"},
                             cost=CostModel(macs_per_s=macs_per_s))
            self._macs_per_s = macs_per_s

        def execute(self, a, b, *, bias=None, activation=None, tile=None,
                    out_dtype=None, precision=None):
            m, k = a.shape
            time.sleep(m * k * b.shape[1] / self._macs_per_s)
            y = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
            return y.astype(out_dtype or a.dtype)

    def pool():
        return [_PacedEngine("fr-a", fast), _PacedEngine("fr-b", fast),
                _PacedEngine("fr-c", fast / 4)]

    def run_wave(rt, step):
        a = jnp.ones((128, 32)); b = jnp.ones((32, 32))
        futs = [rt.submit_gemm(
            a, b, jobset=JobSet.for_gemm(step * 3 + i, 128, 32, 32, 32,
                                         name=f"frw{step}/{i}"),
            tile=(32, 32, 32)) for i in range(3)]
        for f in futs:
            f.result(240)

    def timed_waves(rt, base, n=waves):
        t0 = time.perf_counter()
        for s in range(n):
            run_wave(rt, base + s)
        return n / (time.perf_counter() - t0)

    retry = RetryPolicy(max_attempts=4, heartbeat_timeout_s=0.1,
                        monitor_interval_s=0.02)
    with SynergyRuntime(pool(), name="fr-clean") as rt:
        run_wave(rt, 990)                      # warmup: jit compiles
        clean_fps = timed_waves(rt, 0)
    plan = FaultPlan((FaultSpec("fr-c", "die", at_call=2),), seed=9)
    with SynergyRuntime(wrap_pool(pool(), plan), name="fr-fault",
                        retry=retry) as rt:
        deadline = time.perf_counter() + 60    # detection phase, untimed
        while (rt.stats()["worker_deaths"] < 1
               and time.perf_counter() < deadline):
            run_wave(rt, 100 + rt.stats()["submissions"])
        st = rt.stats()
        recovered_fps = timed_waves(rt, 300)
        st_final = rt.stats()
    rel = recovered_fps / clean_fps
    rows = [
        {"mode": "clean-pool", "fps_wall": clean_fps,
         "fault_recovery_rel": 1.0},
        {"mode": "recovered-pool", "fps_wall": recovered_fps,
         "fault_recovery_rel": rel,
         "worker_deaths": st_final["worker_deaths"],
         "orphan_reseeds": st_final["orphan_reseeds"],
         "retries": st_final["retries"]},
    ]
    return rows, {
        "fault_recovery_rel": round(rel, 4),
        "meets_0_8x": rel >= 0.8,
        "worker_deaths": st_final["worker_deaths"],
        "orphan_reseeds": st_final["orphan_reseeds"],
        "retries": st_final["retries"],
        "waves_to_detect": st["submissions"] // 3,
        "injected": list(map(list, plan.injected)),
    }


def restart_recovery():
    """ISSUE 10 gate: a server restored from a crash must keep at least
    0.8x a clean durable server's steady-state throughput.

    Two durable servers over identical 2-engine PACED pools serve the
    same workload: a clean leg (batch 1 completes normally) and a crash
    leg (a deterministic CrashPlan kills the process mid-batch-1;
    ``SynergyServer.restore`` rebuilds it from the latest snapshot +
    journal-suffix replay into a FRESH pool and finishes batch 1).
    Then both servers run identical timed batches BACK-TO-BACK inside
    each repetition, and the gated ratio is the median per-rep
    restored/clean fps — host drift hits both legs of a rep alike, the
    same pairing discipline serve_throughput uses.  Both legs journal
    every token, so ``restart_recovery_rel`` isolates the cost of
    *having been restored* — leftover replay state, restored caches,
    re-learned rates — not the cost of durability itself.  The restore
    and the batch-1 remnant are untimed, mirroring fault_recovery's
    untimed detection phase: the gate protects the steady restored
    state.  Not shrunk under --smoke (the gated ratio must come from
    the same workload as the committed baseline)."""
    import tempfile
    import time

    import jax
    import jax.numpy as jnp

    from repro.configs import ARCHS, reduced
    from repro.core.serving import Request, SynergyServer
    from repro.engines import CAP_GEMM, CostModel, Engine
    from repro.models import init_model
    from repro.models.cnn import CNNConfig
    from repro.soc import (CrashPlan, Durability, SimulatedCrash,
                           SynergyRuntime)

    cfg = reduced(ARCHS["granite-3-2b"], n_layers=2, d_model=32,
                  n_heads=2, d_ff=64, vocab=128)
    params = init_model(cfg, jax.random.key(0))
    tiny_cnn = CNNConfig(
        name="MNIST-r8", input_hw=8, cin=1, tile=256, layers=(
            ("conv", 8, 3, 1, 1), ("pool", 2),
            ("conv", 16, 3, 1, 1), ("pool", 2), ("fc", 10)))
    pace = 2e8

    class _PacedEngine(Engine):
        def __init__(self, name, macs_per_s):
            super().__init__(name, {CAP_GEMM, "epilogue"},
                             cost=CostModel(macs_per_s=macs_per_s))
            self._macs_per_s = macs_per_s

        def execute(self, a, b, *, bias=None, activation=None, tile=None,
                    out_dtype=None, precision=None):
            m, k = a.shape
            time.sleep(m * k * b.shape[1] / self._macs_per_s)
            y = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
            return y.astype(out_dtype or a.dtype)

    def pool():
        return [_PacedEngine("rr-a", pace), _PacedEngine("rr-b", pace)]

    n_req, new_tokens, plen = 8, 8, 4
    kw = dict(slots=4, max_len=32, prefill_len=plen,
              prefill_cnn=tiny_cnn, max_inflight=1)

    def requests(base):
        return [Request(base + i,
                        jax.random.randint(jax.random.key(i), (plen,), 0,
                                           128),
                        max_new_tokens=new_tokens) for i in range(n_req)]

    def timed_batch(srv, base):
        tok0 = srv.stats.tokens_out
        for r in requests(base):
            srv.submit(r)
        t0 = time.perf_counter()
        srv.run()
        dt = time.perf_counter() - t0
        return (srv.stats.tokens_out - tok0) / dt

    reps = 5
    with tempfile.TemporaryDirectory() as dc, \
            tempfile.TemporaryDirectory() as dx:
        # crash leg prelude: die mid-batch-1, restore into a fresh pool
        # (untimed), finish the remnant (untimed)
        dur_x = Durability(dx, snapshot_every=6)
        with SynergyRuntime(pool(), name="rr-crash") as rt:
            srv = SynergyServer(cfg, params, runtime=rt, durable=dur_x,
                                crash_plan=CrashPlan(at_step=7), **kw)
            try:
                for r in requests(0):
                    srv.submit(r)
                srv.run()
                raise RuntimeError("crash plan never fired")
            except SimulatedCrash:
                pass
            srv._ck.wait()      # flush the async snapshot writer so the
            rt.shutdown()       # tempdir teardown below cannot race it
        with SynergyRuntime(pool(), name="rr-clean") as rt_c, \
                SynergyRuntime(pool(), name="rr-restored") as rt_r:
            srv_c = SynergyServer(cfg, params, runtime=rt_c,
                                  durable=Durability(
                                      dc, snapshot_every=6), **kw)
            for r in requests(0):          # clean batch 1: jit warmup
                srv_c.submit(r)
            srv_c.run()
            srv_r = SynergyServer.restore(cfg, params, durable=dur_x,
                                          runtime=rt_r, **kw)
            srv_r.run()                    # batch-1 remnant, untimed
            ratios, clean_samples, rec_samples = [], [], []
            for rep in range(reps):
                base = (rep + 1) * 1000
                clean_fps = timed_batch(srv_c, base)
                recovered_fps = timed_batch(srv_r, base)
                clean_samples.append(clean_fps)
                rec_samples.append(recovered_fps)
                ratios.append(recovered_fps / clean_fps)
            # graceful close: final snapshot lands, journal closes, and
            # the async writers finish before the tempdirs tear down
            clean_stats = srv_c.close(release_pool=False)
            restored_stats = srv_r.close(release_pool=False)

    # capped at 1.0: a restored server cannot genuinely beat its clean
    # twin — excess is timer noise, and capping keeps the committed
    # baseline from inflating the relative-drop gate
    rel = min(1.0, statistics.median(ratios))
    clean_fps = statistics.median(clean_samples)
    recovered_fps = statistics.median(rec_samples)
    rows = [
        {"mode": "clean-durable", "tokens_per_s_wall": clean_fps,
         "restart_recovery_rel": 1.0,
         "snapshots": clean_stats.snapshots},
        {"mode": "crashed-restored", "tokens_per_s_wall": recovered_fps,
         "restart_recovery_rel": rel,
         "snapshots": restored_stats.snapshots,
         "replayed_tokens": restored_stats.replayed_tokens,
         "replayed_jobs": restored_stats.replayed_jobs},
    ]
    return rows, {
        "restart_recovery_rel": round(rel, 4),
        "meets_0_8x": rel >= 0.8,
        "replayed_tokens": restored_stats.replayed_tokens,
        "restores": restored_stats.restores,
        "snapshots": restored_stats.snapshots,
    }


ALL = {
    "fig9_throughput": fig9_throughput,
    "fig11_latency_heterogeneity": fig11_latency_heterogeneity,
    "fig12_throughput_heterogeneity": fig12_throughput_heterogeneity,
    "fig13_work_stealing": fig13_work_stealing,
    "fig14_cluster_balance": fig14_cluster_balance,
    "table6_utilization": table6_utilization,
    "fig7_mmu_contention": fig7_mmu_contention,
    "table3_4_energy": table3_4_energy,
    "runtime_steal": runtime_steal,
    "quant_pool": quant_pool,
    "qmm_int8x8": qmm_int8x8,
    "serve_throughput": serve_throughput,
    "graph_overlap": graph_overlap,
    "qos_slo": qos_slo,
    "obs_overhead": obs_overhead,
    "fault_recovery": fault_recovery,
    "restart_recovery": restart_recovery,
}
