"""Per-op HBM-traffic breakdown of an archived dry-run HLO — the
"profiler" of the dry-run methodology (EXPERIMENTS §Perf reads these).

    PYTHONPATH=src python -m benchmarks.hlo_breakdown \
        results/dryrun/hlo/<tag>.hlo.zst [top_n]
"""

from __future__ import annotations

import re
import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import zstandard  # noqa: E402

from repro.launch import hlo_analysis as H  # noqa: E402


def breakdown(text: str, top_n: int = 20):
    comps, entry = H._split_computations(text)
    symtabs = {name: H._symbols(lines) for name, lines in comps.items()}
    touched_cache: dict = {}
    rows = []
    stack = set()

    def walk(comp, lines, mult):
        sym = symtabs.get(comp, {})
        for line in lines:
            om = H._OP_RE.match(line)
            if not om:
                continue
            opcode = om.group(3)
            result = om.group(2)
            if opcode == "while":
                bm = H._BODY_RE.search(line)
                cm = H._COND_RE.search(line)
                if bm and bm.group(1) in comps and bm.group(1) not in stack:
                    trips = (H._trip_count(comps[cm.group(1)])
                             if cm and cm.group(1) in comps else 1)
                    stack.add(bm.group(1))
                    walk(bm.group(1), comps[bm.group(1)], mult * trips)
                    stack.discard(bm.group(1))
                continue
            if opcode in H._NO_TRAFFIC_OPS:
                continue
            ops_b = [H._shape_bytes(sym.get(o, ""))
                     for o in H._operands(line, om.end(3))]
            if "dynamic-update-slice" in line:
                t = 2.0 * (sum(ops_b) - max(ops_b, default=0))
            elif "dynamic-slice" in line and opcode != "fusion":
                t = 2.0 * H._shape_bytes(result)
            else:
                if opcode == "fusion":
                    cm4 = H._CALL_RE.search(line)
                    if cm4 and cm4.group(1) in comps:
                        body = cm4.group(1)
                        if body not in touched_cache:
                            touched_cache[body] = H._fusion_touched(
                                comps[body], symtabs.get(body, {}))
                        tmap = touched_cache[body]
                        ops_b = [min(b, tmap.get(i, b))
                                 for i, b in enumerate(ops_b)]
                t = H._shape_bytes(result) + sum(ops_b)
            mm = re.search(r'op_name="([^"]*)"', line)
            name = mm.group(1).split("/")[-1] if mm else opcode
            rows.append((t * mult, mult, opcode, name, result[:48]))

    walk(entry, comps.get(entry, []), 1.0)
    rows.sort(key=lambda r: -r[0])
    return rows[:top_n]


def main():
    path = sys.argv[1]
    top_n = int(sys.argv[2]) if len(sys.argv) > 2 else 20
    text = zstandard.ZstdDecompressor().decompress(
        open(path, "rb").read()).decode()
    total = H.analyze_hlo(text)
    print(f"flops={total.flops/1e12:.2f}TF hbm={total.hbm_bytes/1e9:.1f}GB "
          f"coll={ {k: round(v/1e9,2) for k,v in total.coll_bytes_by_type.items()} }")
    for t, mult, opcode, name, res in breakdown(text, top_n):
        print(f"{t/1e9:9.1f} GB x{mult:6.0f} {opcode:10s} {name[:44]:44s} {res}")


if __name__ == "__main__":
    main()
