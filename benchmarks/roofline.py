"""Roofline derivation from the dry-run artifacts (EXPERIMENTS §Roofline).

Per (arch x shape x mesh) cell, three terms in SECONDS per step:

  compute    = HLO_dot_FLOPs_per_device / 197e12        (bf16 MXU peak)
  memory     = HLO_HBM_bytes_per_device / 819e9         (HBM BW)
  collective = link_bytes_per_device / 50e9             (ICI per link)

HLO_* come from the loop-aware analyzer (repro.launch.hlo_analysis) over the
per-device partitioned module.  link_bytes applies the ring model: an
all-reduce moves ~2x its result bytes per device; all-gather /
reduce-scatter / all-to-all / collective-permute ~1x.

Also reported: MODEL_FLOPS (6·N_active·D train, 2·N·D inference),
MODEL_FLOPS / global HLO FLOPs (useful-compute ratio — catches remat and
padding waste), the dominant term, and the roofline fraction
compute / max(terms) — the score the §Perf hillclimb drives up.
"""

from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import ARCHS, SHAPES           # noqa: E402
from repro.models import model_flops               # noqa: E402

PEAK_FLOPS = 197e12        # bf16 per chip (given)
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

_RING_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def link_bytes(acct: dict) -> float:
    return sum(_RING_FACTOR.get(k, 1.0) * v
               for k, v in acct.get("bytes_by_type", {}).items())


def _advice(dom: str, rec: dict, cfg) -> str:
    if dom == "memory":
        if rec["kind"] in ("train", "prefill") and cfg.n_heads:
            return ("fp32 attention blocks spill to HBM in the XLA engine; "
                    "Pallas flash kernel keeps them in VMEM (+bf16 scores)")
        return ("decode is weight/KV-bandwidth bound; int8 weights or "
                "wider batch raise arithmetic intensity")
    if dom == "collective":
        return ("shard/replicate boundary churn; move the psum off the "
                "critical path (reduce-scatter + overlap) or change the "
                "sharded dim")
    return "near MXU roofline; only tile/layout tuning left"


def _decode_min_bytes(cfg, cell, chips: int) -> float:
    """Ideal decode traffic per device per step: every active weight read
    once + the KV cache (or SSM state) read once — the bandwidth roofline
    decode cells are judged against."""
    psize = 1 if cfg.param_dtype == "int8" else (
        2 if cfg.param_dtype == "bfloat16" else 4)
    w = cfg.n_active_params() * psize / chips
    hd = cfg.resolved_head_dim
    csize = 1 if cfg.cache_dtype == "int8" else 2
    if cfg.family == "ssm":
        cache = (cfg.n_layers * cell.global_batch * cfg.ssm_heads
                 * cfg.ssm_head_dim * cfg.ssm_state * 4) / chips
    else:
        layers_with_kv = (cfg.n_layers if cfg.family != "hybrid"
                          else cfg.n_layers // max(1, cfg.attn_every))
        cache = (2 * layers_with_kv * cell.global_batch * cfg.n_kv_heads
                 * cell.seq_len * hd * csize) / chips
        if cfg.family == "hybrid":
            cache += (cfg.n_layers * cell.global_batch * cfg.ssm_heads
                      * cfg.ssm_head_dim * cfg.ssm_state * 4) / chips
    return w + cache


def roofline_row(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    acct = rec.get("hlo_accounting", {})
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    compute = acct.get("flops", 0.0) / PEAK_FLOPS
    memory = acct.get("hbm_bytes", 0.0) / HBM_BW
    coll = link_bytes(acct) / LINK_BW
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dom = max(terms, key=terms.get)
    cfg = ARCHS[rec["arch"]]
    cell = SHAPES[rec["shape"]]
    mf = model_flops(cfg, cell)
    hlo_global = acct.get("flops", 0.0) * chips
    bw_eff = None
    if rec["kind"] == "decode" and acct.get("hbm_bytes"):
        bw_eff = _decode_min_bytes(cfg, cell, chips) / acct["hbm_bytes"]
    return {
        "bw_efficiency": bw_eff,
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"],
        "compute_s": compute, "memory_s": memory, "collective_s": coll,
        "dominant": dom,
        "roofline_fraction": compute / max(max(terms.values()), 1e-30),
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "peak_mem_gb": rec.get("memory", {}).get(
            "peak_memory_in_bytes", 0) / 1e9,
        "advice": _advice(dom, rec, cfg),
    }


def build_table(dryrun_dir: str = "results/dryrun",
                mesh: str | None = "16x16") -> list[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(f))
        if mesh and rec.get("mesh") != mesh:
            continue
        if rec.get("status") == "skipped":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "skipped": rec["reason"]})
            continue
        row = roofline_row(rec)
        if row:
            rows.append(row)
    return rows


def to_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| roofline frac | useful 6ND/HLO | peak GB/dev | fix |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | skip | — "
                       f"| — | — | {r['skipped'][:60]} |\n")
            continue
        frac = (f"{r['roofline_fraction']:.3f}"
                if r.get("bw_efficiency") is None
                else f"bw {r['bw_efficiency']:.2f}")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} "
            f"| {r['memory_s']:.3f} | {r['collective_s']:.3f} "
            f"| **{r['dominant']}** | {frac} "
            f"| {r['useful_ratio']:.2f} | {r['peak_mem_gb']:.1f} "
            f"| {r['advice'][:70]} |\n")
    return "".join(out)


def main() -> None:
    for mesh in ("16x16", "2x16x16"):
        rows = build_table(mesh=mesh)
        os.makedirs("results", exist_ok=True)
        with open(f"results/roofline_{mesh}.json", "w") as f:
            json.dump(rows, f, indent=1)
        print(f"==== mesh {mesh} ({len(rows)} cells) ====")
        print(to_markdown(rows))


if __name__ == "__main__":
    main()
